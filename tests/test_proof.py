"""Proof subsystem: stateless membership/absence/lineage/attestation
verification, forged-proof rejection (any mutated byte => InvalidProof),
the replica/cluster auditor, and the blockchain light client.

The verifiers take ONLY a trusted anchor (root cid / head uid /
attestation) plus proof bytes — statelessness is by construction: no
test hands a store to a verify_* function."""
import dataclasses

import numpy as np
import pytest

from repro.core import Cluster, FBlob, FList, FMap, FSet, ForkBase
from repro.core import chunk as ck
from repro.core.chunker import ChunkParams
from repro.core.postree import POSTree
from repro.proof import (Attestation, InvalidProof, LineageProof,
                         MembershipProof, prove_absence, prove_head,
                         prove_lineage, prove_member, verify_head,
                         verify_lineage, verify_member, verify_member_many,
                         verify_version)
from repro.storage import MemoryBackend, ReplicatedBackend, TamperedChunk

PARAMS = ChunkParams(q=8)           # 256 B chunks: multi-level test trees


@pytest.fixture
def db():
    return ForkBase(MemoryBackend(), PARAMS)


def _tree(db, key):
    obj = db.get(key).obj
    return obj.data, POSTree.from_root(db.store, obj.type, obj.data,
                                       PARAMS)


def _mutations(raw, step=1):
    for i in range(0, len(raw), step):
        yield raw[:i] + bytes([raw[i] ^ 0x5A]) + raw[i + 1:]


def _flip_tail(raw):
    return raw[:-1] + bytes([raw[-1] ^ 0xFF])


# ------------------------------------------------------------- membership

def test_member_by_key_map(db, rng):
    m = {b"k%05d" % i: rng.bytes(20) for i in range(500)}
    db.put("m", FMap(m))
    root, tree = _tree(db, "m")
    assert tree.height > 1                      # a real multi-level tree
    proof = prove_member(tree, key=b"k00321")
    claim = verify_member(root, proof.to_bytes())
    assert claim.key == b"k00321" and claim.value == m[b"k00321"]


def test_member_by_pos_all_kinds(db, rng):
    data = rng.bytes(9000)
    db.put("b", FBlob(data))
    els = [b"el-%05d" % i for i in range(700)]
    db.put("l", FList(els))
    db.put("s", FSet(els))
    db.put("m", FMap({e: e[::-1] for e in els}))
    for key, want in [("b", lambda p: data[p:p + 1]),
                      ("l", lambda p: els[p]),
                      ("s", lambda p: sorted(els)[p]),
                      ("m", lambda p: ck.pack_kv(sorted(els)[p],
                                                 sorted(els)[p][::-1]))]:
        root, tree = _tree(db, key)
        for pos in (0, 17, tree.total_count - 1):
            claim = verify_member(root, prove_member(tree, pos=pos))
            assert claim.value == want(pos), key


def test_absence_with_enclosure(db, rng):
    keys = [b"k%05d" % i for i in range(0, 1000, 2)]     # evens only
    db.put("m", FMap({k: b"v" for k in keys}))
    root, tree = _tree(db, "m")
    claim = verify_member(root, prove_absence(tree, b"k00301").to_bytes())
    assert claim.enclosure == (b"k00300", b"k00302")
    # off both ends
    lo = verify_member(root, prove_absence(tree, b"a"))
    assert lo.enclosure[0] is None
    hi = verify_member(root, prove_absence(tree, b"z"))
    assert hi.enclosure[1] is None
    # present key cannot be proven absent
    with pytest.raises(KeyError):
        prove_absence(tree, b"k00300")


def test_verify_needs_matching_root(db, rng):
    db.put("a", FMap({b"x%03d" % i: b"1" for i in range(300)}))
    db.put("b", FMap({b"x%03d" % i: b"2" for i in range(300)}))
    root_a, tree_a = _tree(db, "a")
    root_b, _ = _tree(db, "b")
    proof = prove_member(tree_a, key=b"x007")
    verify_member(root_a, proof)
    with pytest.raises(InvalidProof):
        verify_member(root_b, proof)            # wrong trust anchor


def test_verify_member_many_batches_and_isolates_failures(db, rng):
    db.put("m", FMap({b"k%04d" % i: rng.bytes(8) for i in range(400)}))
    root, tree = _tree(db, "m")
    items = [(root, prove_member(tree, pos=i * 7)) for i in range(30)]
    claims = verify_member_many(items)
    assert len(claims) == 30
    bad = dataclasses.replace(items[3][1], value=b"forged")
    mixed = items[:3] + [(root, bad)] + items[4:]
    res = verify_member_many(mixed, strict=False)
    assert isinstance(res[3], InvalidProof)
    assert sum(1 for r in res if isinstance(r, InvalidProof)) == 1
    with pytest.raises(InvalidProof):
        verify_member_many(mixed)


# ---------------------------------------------------------------- lineage

def test_lineage_proof_and_depth(db, rng):
    uids = [db.put("k", FBlob(b"v%d" % i)) for i in range(6)]
    proof = prove_lineage(db.store, uids[-1], uids[1])
    objs = verify_lineage(uids[-1], uids[1], proof.to_bytes())
    assert len(objs) - 1 == 4                   # derivation distance
    assert [o.uid for o in objs] == list(reversed(uids[1:]))
    assert objs[-1].depth == 1                  # authenticated depth field
    # self-proof: distance 0
    assert len(verify_lineage(uids[0], uids[0],
                              prove_lineage(db.store, uids[0],
                                            uids[0]))) == 1


def test_lineage_through_merge(db, rng):
    base = {b"k%02d" % i: b"v" for i in range(40)}
    db.put("k", FMap(base))
    anchor = db.get("k").uid
    db.fork("k", "master", "side")
    m1 = db.get("k", "side").map()
    m1.set(b"side-only", b"1")
    db.put("k", m1, "side")
    m2 = db.get("k").map()
    m2.set(b"master-only", b"2")
    db.put("k", m2)
    merged = db.merge("k", "master", "side")
    proof = prove_lineage(db.store, merged, anchor)
    assert len(verify_lineage(merged, anchor, proof)) >= 2


def test_spliced_history_rejected(db, rng):
    """A proof from a different branch's history cannot authenticate
    against this head, and non-ancestors cannot be proven at all."""
    db.put("k", FBlob(b"base"))
    db.fork("k", "master", "evil")
    db.put("k", FBlob(b"good"))
    db.put("k", FBlob(b"forged"), "evil")
    good, evil = db.get("k").uid, db.get("k", "evil").uid
    with pytest.raises(KeyError):
        prove_lineage(db.store, good, evil)     # not an ancestor
    proof = prove_lineage(db.store, evil, db.get("k").obj.bases[0])
    with pytest.raises(InvalidProof):
        verify_lineage(good, db.get("k").obj.bases[0], proof)


def test_verify_version_binds_uid(db, rng):
    uid = db.put("k", FMap({b"a": b"1"}))
    raw = db.prove_version(uid)
    obj = verify_version(uid, raw)
    assert obj.uid == uid and obj.type == ck.MAP
    with pytest.raises(InvalidProof):
        verify_version(uid, raw[:-1] + bytes([raw[-1] ^ 1]))


# ------------------------------------------------------------ attestation

def test_attestation_commits_every_head(db, rng):
    for i in range(7):
        db.put("k%d" % i, FBlob(b"v%d" % i))
    db.fork("k0", "master", "feature")
    att = db.attest(context=b"epoch-1", secret=b"hmac-key")
    att2 = Attestation.from_bytes(att.to_bytes())
    for key, tag in [(b"k0", "master"), (b"k0", "feature"),
                     (b"k5", "master")]:
        proof = db.prove_head(key, tag)
        k, t, uid = verify_head(att2, proof.to_bytes(), secret=b"hmac-key")
        assert (k, t) == (key, tag)
        assert uid == db.branches.head(key, tag)


def test_attestation_covers_untagged_heads(db, rng):
    base = db.put("k", FBlob(b"v0"))
    db.put("k", FBlob(b"v1"), base_uid=base)    # FoC: untagged head
    foc = db.list_untagged_branches("k")[0]
    att = db.attest()
    _, tag, uid = verify_head(att, db.prove_head("k", uid=foc))
    assert uid == foc


def test_stale_attestation_rejects_new_head(db, rng):
    db.put("k", FBlob(b"v0"))
    att = db.attest(secret=b"s")
    db.put("k", FBlob(b"v1"))                   # head moves on
    with pytest.raises(InvalidProof):
        verify_head(att, db.prove_head("k", "master"), secret=b"s")


def test_wrong_secret_rejected(db, rng):
    db.put("k", FBlob(b"v"))
    att = db.attest(secret=b"right")
    proof = db.prove_head("k", "master")
    verify_head(att, proof, secret=b"right")
    with pytest.raises(InvalidProof):
        verify_head(att, proof, secret=b"wrong")


def test_cluster_attestation_per_servlet():
    cl = Cluster(3)
    for i in range(8):
        cl.put("key%d" % i, FBlob(b"v%d" % i))
    catt, atts = cl.attest(context=b"e", secret=b"s")
    assert catt.count == 3 and len(atts) == 3
    assert sum(a.count for a in atts) == 8
    # drill into one servlet: its attestation commits its keys
    for ni, nd in enumerate(cl.nodes):
        for key in nd.servlet.branches.keys():
            proof = prove_head(nd.servlet.branches, key, "master")
            verify_head(atts[ni], proof, secret=b"s")


# -------------------------------------------------- forged-proof rejection
#
# Soundness property: mutating any proof byte either fails verification
# or shifts the proof onto a DIFFERENT claim that is still TRUE of the
# underlying data (e.g. the absence of some other genuinely absent key).
# No mutation may ever make a false statement verify.

def _assert_all_mutations_rejected(verify, raw, step=1):
    for mut in _mutations(raw, step):
        with pytest.raises(InvalidProof):
            verify(mut)


def _assert_mutations_sound(verify, raw, orig_claim, truth, step=1):
    key_of = lambda c: (c.mode, c.pos, c.key, c.value)   # noqa: E731
    for mut in _mutations(raw, step):
        try:
            c = verify(mut)
        except InvalidProof:
            continue
        assert key_of(c) != key_of(orig_claim), "same claim, forged bytes"
        truth(c)


def test_forged_membership_rejected_exhaustive(db, rng):
    m = {b"k%04d" % i: rng.bytes(12) for i in range(300)}
    db.put("m", FMap(m))
    root, tree = _tree(db, "m")

    def truth(c):
        if c.mode == 2:                    # member-by-key: must be real
            assert m.get(c.key) == c.value
        elif c.mode == 1:                  # member-by-pos
            k, v = sorted(m.items())[c.pos]
            assert ck.pack_kv(k, v) == c.value
        else:                              # absence: must be truly absent
            assert c.key not in m
    for proof in (prove_member(tree, key=b"k0123"),
                  prove_member(tree, pos=77),
                  prove_absence(tree, b"k0123x")):
        orig = verify_member(root, proof.to_bytes())
        _assert_mutations_sound(lambda mb: verify_member(root, mb),
                                proof.to_bytes(), orig, truth)


def test_forged_lineage_rejected_exhaustive(db, rng):
    uids = [db.put("k", FBlob(b"v%d" % i)) for i in range(4)]
    raw = prove_lineage(db.store, uids[-1], uids[0]).to_bytes()
    _assert_all_mutations_rejected(
        lambda m: verify_lineage(uids[-1], uids[0], m), raw)


def test_forged_attestation_rejected_exhaustive(db, rng):
    for i in range(5):
        db.put("k%d" % i, FBlob(b"v"))
    att_raw = db.attest(context=b"ctx", secret=b"s").to_bytes()
    hp_raw = db.prove_head(b"k2", "master").to_bytes()
    verify_head(att_raw, hp_raw, secret=b"s")
    _assert_all_mutations_rejected(
        lambda m: verify_head(m, hp_raw, secret=b"s"), att_raw)
    _assert_all_mutations_rejected(
        lambda m: verify_head(att_raw, m, secret=b"s"), hp_raw)


# ------------------------------------------------- hypothesis properties

def test_proof_roundtrip_property(db):
    """Round-trip for every chunkable type + forged rejection, under
    randomized contents/positions (hypothesis)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(data=st.data(), seed=st.integers(0, 2**31 - 1),
           n=st.integers(1, 120))
    def prop(data, seed, n):
        rng = np.random.default_rng(seed)
        store = MemoryBackend()
        kind = data.draw(st.sampled_from(["blob", "list", "set", "map"]))
        if kind == "blob":
            payload = rng.bytes(n * 37 + 1)
            tree = POSTree.build_bytes(store, payload, PARAMS)
        else:
            els = sorted({b"e%06d-%d" % (i, seed % 97)
                          for i in range(n)})
            if kind == "map":
                tree = POSTree.build_elements(
                    store, ck.MAP, [ck.pack_kv(e, e[::-1]) for e in els],
                    keys=els, params=PARAMS)
            elif kind == "set":
                tree = POSTree.build_elements(
                    store, ck.SET, [ck.pack_lv(e) for e in els],
                    keys=els, params=PARAMS)
            else:
                tree = POSTree.build_elements(
                    store, ck.LIST, [ck.pack_lv(e) for e in els],
                    params=PARAMS)
        root = tree.root_cid
        pos = data.draw(st.integers(0, tree.total_count - 1))
        proof = prove_member(tree, pos=pos)
        claim = verify_member(root, proof.to_bytes())
        assert claim.pos == pos

        def item_at(p):
            if kind == "blob":
                return payload[p:p + 1]
            if kind == "map":
                return ck.pack_kv(els[p], els[p][::-1])
            return els[p]
        assert claim.value == item_at(pos)
        # soundness under mutation: flip one random byte — the proof
        # must fail, or prove a different still-true positional claim
        raw = proof.to_bytes()
        i = data.draw(st.integers(0, len(raw) - 1))
        mut = raw[:i] + bytes([raw[i] ^ data.draw(
            st.integers(1, 255))]) + raw[i + 1:]
        try:
            c = verify_member(root, mut)
        except InvalidProof:
            c = None
        if c is not None:
            assert (c.mode, c.pos, c.value) != (claim.mode, pos,
                                                claim.value)
            if c.mode == 1:
                assert c.value == item_at(c.pos)

    prop()


def test_lineage_and_attest_forgery_property(db):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    uids = [db.put("k", FBlob(b"version-%d" % i)) for i in range(5)]
    lin_raw = prove_lineage(db.store, uids[-1], uids[0]).to_bytes()
    att_raw = db.attest(secret=b"s").to_bytes()
    hp_raw = db.prove_head(b"k", "master").to_bytes()

    @settings(max_examples=60, deadline=None)
    @given(which=st.sampled_from(["lineage", "attestation", "head"]),
           data=st.data())
    def prop(which, data):
        raw = {"lineage": lin_raw, "attestation": att_raw,
               "head": hp_raw}[which]
        i = data.draw(st.integers(0, len(raw) - 1))
        x = data.draw(st.integers(1, 255))
        mut = raw[:i] + bytes([raw[i] ^ x]) + raw[i + 1:]
        with pytest.raises(InvalidProof):
            if which == "lineage":
                verify_lineage(uids[-1], uids[0], mut)
            elif which == "attestation":
                verify_head(mut, hp_raw, secret=b"s")
            else:
                verify_head(att_raw, mut, secret=b"s")

    prop()


# ------------------------------------------------------------ verify-on-get

def test_verify_on_get_counts_and_catches(rng):
    store = MemoryBackend()
    db = ForkBase(store, PARAMS, verify_get=True)
    uid = db.put("k", FBlob(rng.bytes(2000)))
    db.get("k")
    assert store.stats.verifies == 1 and store.stats.verify_failures == 0
    store._data[uid] = _flip_tail(store._data[uid])
    with pytest.raises(TamperedChunk):
        db.get("k")
    assert store.stats.verify_failures == 1
    # per-call override wins over the engine default
    db2 = ForkBase(MemoryBackend(), PARAMS)
    u2 = db2.put("k", FBlob(b"x"))
    db2.store._data[u2] = _flip_tail(db2.store._data[u2])
    db2.get("k")                                # default: unchecked
    with pytest.raises(TamperedChunk):
        db2.get("k", verify=True)


# ----------------------------------------------------------------- auditor

def test_replica_audit_reports_offending_node(rng):
    rb = ReplicatedBackend([MemoryBackend() for _ in range(3)], k=2)
    db = ForkBase(rb, PARAMS)
    db.put("k", FBlob(rng.bytes(30_000)))
    assert rb.audit(sample=1000).ok
    cid = sorted(rb.iter_cids())[3]
    victim = None
    for si, s in enumerate(rb.stores):
        if s.has(cid):
            raw = s._data[cid]
            s._data[cid] = raw[:-1] + bytes([raw[-1] ^ 1])
            victim = si
            break
    rep = rb.audit(sample=1000)
    assert not rep.ok
    assert any(f.kind == "corrupt" and f.node == f"replica{victim}"
               and f.cid == cid for f in rep.findings)


def test_replica_audit_reports_missing_copy(rng):
    rb = ReplicatedBackend([MemoryBackend() for _ in range(3)], k=2)
    rb.put_many([ck.encode_chunk(3, rng.bytes(100) + bytes([i]))
                 for i in range(20)])
    cid = sorted(rb.iter_cids())[0]
    for s in rb.stores:                          # drop ONE ring copy
        if s.has(cid):
            del s._data[cid]
            break
    rep = rb.audit(sample=1000)
    assert any(f.kind == "missing" and f.cid == cid for f in rep.findings)


def test_engine_audit_end_to_end(db, rng):
    for i in range(4):
        db.put("k%d" % i,
               FMap({b"e%03d" % j: rng.bytes(16) for j in range(80)}))
        db.put("k%d" % i,
               FMap({b"e%03d" % j: rng.bytes(16) for j in range(80)}))
    rep = db.audit(secret=b"s")
    assert rep.ok and rep.proofs_verified > 0 and rep.heads_checked == 4


def test_cluster_audit_catches_node_corruption(rng):
    cl = Cluster(3, params=PARAMS)
    for i in range(6):
        cl.put("key%d" % i, FBlob(rng.bytes(8000)))
    assert cl.audit(sample=10_000, secret=b"s").ok
    nd = cl.nodes[2]
    cid = sorted(nd.store._data)[1]
    raw = nd.store._data[cid]
    nd.store._data[cid] = raw[:-1] + bytes([raw[-1] ^ 0xFF])
    rep = cl.audit(sample=10_000, secret=b"s")
    assert not rep.ok
    assert any(f.node == "node2" for f in rep.findings)


def test_audit_reports_instead_of_raising_on_verify_store(rng):
    """A verify-enabled store raises TamperedChunk on Get; the auditor
    must absorb that into a 'corrupt' finding, not crash."""
    store = MemoryBackend(verify=True)
    db = ForkBase(store, PARAMS)
    uid = db.put("k", FBlob(rng.bytes(4000)))
    store._data[uid] = _flip_tail(store._data[uid])
    rep = db.audit(secret=b"s")
    assert not rep.ok
    assert any(f.kind == "corrupt" and f.cid == uid for f in rep.findings)
    # replicas: same containment
    rb = ReplicatedBackend([MemoryBackend(verify=True) for _ in range(3)],
                           k=2)
    cid = rb.put(ck.encode_chunk(3, rng.bytes(500)))
    for s in rb.stores:
        if s.has(cid):
            s._data[cid] = _flip_tail(s._data[cid])
    rep = rb.audit(sample=10)
    assert not rep.ok and all(f.kind == "corrupt" for f in rep.findings)
    # cluster: a verify-enabled node with a corrupt chunk
    cl = Cluster(2, params=PARAMS, verify=True)
    cl.put("key", FBlob(rng.bytes(4000)))
    nd = cl.nodes[0] if cl.nodes[0].store._data else cl.nodes[1]
    c0 = sorted(nd.store._data)[0]
    nd.store._data[c0] = _flip_tail(nd.store._data[c0])
    rep = cl.audit(sample=10_000)
    assert not rep.ok
    assert any(f.kind == "corrupt" for f in rep.findings)


def test_light_client_rejects_empty_lineage(rng):
    from repro.apps.blockchain import ForkBaseLedger, LightClient
    led = ForkBaseLedger()
    led.write("c", "k", b"v")
    led.commit()
    lc = LightClient(led.db.get("chain").uid)
    sp = led.prove_state("c", "k")
    empty = bytes([0xFB, 4]) + b"\x00\x00"        # n=0 lineage, parses
    forged = dataclasses.replace(sp, lineage=empty)
    with pytest.raises(InvalidProof):
        lc.verify_state(forged, "c", "k")


def test_light_client_rejects_forged_empty_value(rng):
    """A server cannot present a non-empty state as empty by dropping
    the value leaf proofs; a genuinely empty state still verifies."""
    from repro.apps.blockchain import ForkBaseLedger, LightClient
    led = ForkBaseLedger()
    led.write("bank", "alice", b"100 coins")
    led.write("bank", "emptied", b"")
    led.commit()
    lc = LightClient(led.db.get("chain").uid)
    sp = led.prove_state("bank", "alice")
    forged = dataclasses.replace(sp, value=b"", value_proofs=())
    with pytest.raises(InvalidProof):
        lc.verify_state(forged, "bank", "alice")
    _, val = lc.verify_state(led.prove_state("bank", "emptied"),
                             "bank", "emptied")
    assert val == b""


def test_make_backend_sharded_honors_verify(rng):
    from repro.storage import make_backend
    be = make_backend("sharded", shards=2, verify=True)
    cid = be.put(ck.encode_chunk(3, rng.bytes(300)))
    shard = next(s for s in be.shards if s.has(cid))
    shard._data[cid] = _flip_tail(shard._data[cid])
    with pytest.raises(TamperedChunk):
        be.get(cid)


def test_prove_head_defaults_to_master(db, rng):
    db.put("k", FBlob(b"v"))
    att = db.attest(secret=b"s")
    _, tag, uid = verify_head(att, db.prove_head("k"), secret=b"s")
    assert tag == "master" and uid == db.branches.head(b"k", "master")


def test_cluster_audit_detects_routing_divergence(rng):
    cl = Cluster(3, params=PARAMS)
    cl.put("key", FBlob(b"v"))
    home = cl._home_index("key")
    rogue = cl.nodes[(home + 1) % 3].servlet
    rogue.branches.set_head(b"key", "master", cl.get("key").uid)
    rep = cl.audit(sample=100)
    assert any(f.kind == "diverged" for f in rep.findings)


# ------------------------------------------------------------ light client

def test_light_client_blockchain(rng):
    from repro.apps.blockchain import ForkBaseLedger, LightClient
    led = ForkBaseLedger()
    for h in range(3):
        led.write("bank", "alice", rng.bytes(150) + b"@h%d" % h)
        led.write("bank", "bob", rng.bytes(150))
        led.commit()
    lc = LightClient(led.db.get("chain").uid)
    assert lc.verify_block(led.prove_block(0), led.block_uid(0)) == 2
    for h in (2, 0):
        sp = led.prove_state("bank", "alice", height=h)
        dist, val = lc.verify_state(sp, "bank", "alice")
        assert dist == 2 - h and val.endswith(b"@h%d" % h)
    # a proof for bob cannot masquerade as alice's state
    sp = led.prove_state("bank", "bob")
    with pytest.raises(InvalidProof):
        lc.verify_state(sp, "bank", "alice")
    # forged value bytes are rejected
    sp = led.prove_state("bank", "alice")
    forged = dataclasses.replace(sp, value=sp.value[:-1] + b"\x00")
    with pytest.raises(InvalidProof):
        lc.verify_state(forged, "bank", "alice")


# ------------------------------------------------------------- proof sizes

def test_proof_size_grows_logarithmically(rng):
    sizes = []
    for n in (200, 2000, 20000):
        store = MemoryBackend()
        els = [b"k%07d" % i for i in range(n)]
        tree = POSTree.build_elements(
            store, ck.SET, [ck.pack_lv(e) for e in els], keys=els,
            params=PARAMS)
        proofs = [prove_member(tree, pos=int(p)).size
                  for p in rng.integers(0, n, 16)]
        sizes.append(sum(proofs) / len(proofs))
    # 100x the elements must cost far less than 100x the proof bytes
    assert sizes[2] < sizes[0] * 8


def test_member_proof_wire_roundtrip(db, rng):
    db.put("m", FMap({b"k%03d" % i: rng.bytes(5) for i in range(200)}))
    _, tree = _tree(db, "m")
    p = prove_member(tree, key=b"k055")
    assert MembershipProof.from_bytes(p.to_bytes()) == p
    lp = LineageProof((db.prove_version(db.get("m").uid),))
    assert LineageProof.from_bytes(lp.to_bytes()) == lp
