"""Delta attestations, the GC epoch handshake, proof caching and the
continuous audit daemon (ISSUE 5 tentpole).

Covers:
  * delta-maintained attestation roots are bit-identical to full
    rebuilds under randomized branch-table churn, with O(path) hash
    work after single-head updates;
  * forge sweep: every single-bit flip of a delta-maintained root must
    fail verify_head;
  * malformed committed entries surface as InvalidProof, never raw
    struct errors (decode_entry framing validation);
  * the attest-vs-sweep orphaning race: heads committed by an
    attestation stay provable through the next collection (EpochFence)
    and are rescued from a live sweep (attest_fence);
  * per-root proof cache + persistent verify memo;
  * AuditDaemon: exponential backoff per clean node, immediate
    re-audit + quarantine on a finding, release.
"""
import pytest

from repro.core import Cluster, FBlob, FMap, ForkBase
from repro.core.chunker import ChunkParams
from repro.proof import (InvalidProof, VerifyMemo, attest_heads,
                         attestation_epoch, verify_head,
                         verify_member_many)
from repro.proof.attest import (encode_entry, entry_leaves, merkle_root,
                                prove_entry)
from repro.proof.delta import DeltaAttestor
from repro.storage import MemoryBackend

PARAMS = ChunkParams(q=8)


@pytest.fixture
def db():
    return ForkBase(MemoryBackend(), PARAMS)


# ---------------------------------------------------------- delta == full

def test_delta_root_matches_full_rebuild_under_churn(db, rng):
    """Random put/fork/remove/rename/FoC churn: after every mutation the
    delta-maintained root must equal a from-scratch attest_heads."""
    da = DeltaAttestor(db.branches)
    keys = [b"k%02d" % i for i in range(6)]
    for _step in range(150):
        op = int(rng.integers(0, 100))
        k = keys[int(rng.integers(0, len(keys)))]
        tags = sorted(db.branches.tagged(k))
        try:
            if op < 45:
                db.put(k, FBlob(rng.bytes(40)),
                       tags[int(rng.integers(0, len(tags)))]
                       if tags and op < 30 else "master")
            elif op < 60 and tags:
                db.fork(k, tags[int(rng.integers(0, len(tags)))],
                        "b%d" % int(rng.integers(0, 5)))
            elif op < 75 and tags:
                db.remove(k, tags[int(rng.integers(0, len(tags)))])
            elif op < 85 and tags:
                db.branches.rename(k, tags[int(rng.integers(0, len(tags)))],
                                   "r%d" % int(rng.integers(0, 5)))
            else:
                h = db.branches.head(k, "master")
                if h is not None:
                    db.put(k, FBlob(rng.bytes(30)), base_uid=h)  # FoC
        except (KeyError, ValueError):
            pass
        want = attest_heads(db.branches)
        got = da.attest()
        assert got.root == want.root and got.count == want.count
    assert da.stats.full_rebuilds == 1          # only the first attest
    assert da.stats.delta_refreshes > 50


def test_delta_update_rehashes_one_path(db):
    """k single-head updates cost O(k log n) hashes, not O(n)."""
    n = 256
    for i in range(n):
        db.put(b"key%04d" % i, FBlob(b"v%d" % i))
    da = DeltaAttestor(db.branches)
    da.attest()                                  # full build
    h0 = da.stats.leaf_hashes + da.stats.node_hashes
    assert da.stats.leaf_hashes >= n
    for i in (3, 99, 200):                       # 3 single-head updates
        db.put(b"key%04d" % i, FBlob(b"w%d" % i))
    att = da.attest()
    dh = da.stats.leaf_hashes + da.stats.node_hashes - h0
    # 3 in-place paths: 3 leaves + 3 * ceil(log2 n) nodes, far under n
    assert dh <= 3 * (1 + 10)
    assert att.root == attest_heads(db.branches).root


def test_delta_prove_serves_valid_paths_from_resident_tree(db, rng):
    for i in range(31):
        db.put(b"k%02d" % i, FBlob(rng.bytes(16)))
    db.fork(b"k03", "master", "side")
    att = db.attest(secret=b"s")
    for key, tag in [(b"k00", "master"), (b"k03", "side"),
                     (b"k30", "master")]:
        k, t, uid = verify_head(att, db.prove_head(key, tag).to_bytes(),
                                secret=b"s")
        assert (k, t) == (key, tag)
        assert uid == db.branches.head(key, tag)


def test_delta_survives_hash_algorithm_swap(db, rng):
    from repro.core import hashing
    db.put("k", FBlob(b"v0"))
    att_sha = db.attest()
    hashing.use_fphash()
    try:
        db.put("k", FBlob(b"v1"))
        att_fp = db.attest()                     # forced full rebuild
        assert att_fp.root == attest_heads(db.branches).root
        assert att_fp.root != att_sha.root
    finally:
        hashing.use_sha256()
    assert db.attest().root == attest_heads(db.branches).root
    assert db._delta_attestor.stats.full_rebuilds >= 3


# ------------------------------------------------------------ forge sweep

def test_every_root_bitflip_fails_verify_head(db, rng):
    """Forge sweep over a DELTA-maintained attestation: flipping any
    single bit of the root must break every head proof."""
    import dataclasses
    for i in range(17):
        db.put(b"k%02d" % i, FBlob(rng.bytes(12)))
    db.attest()                                  # build the tree
    for i in (1, 5, 9):                          # then delta-update heads
        db.put(b"k%02d" % i, FBlob(rng.bytes(12)))
    att = db.attest()
    assert att.root == attest_heads(db.branches).root
    proof = db.prove_head(b"k05", "master").to_bytes()
    verify_head(att, proof)                      # sanity: valid as-is
    for byte in range(32):
        for bit in range(8):
            forged_root = (att.root[:byte]
                           + bytes([att.root[byte] ^ (1 << bit)])
                           + att.root[byte + 1:])
            forged = dataclasses.replace(att, root=forged_root)
            with pytest.raises(InvalidProof):
                verify_head(forged, proof)


# ------------------------------------------------- malformed entry decode

def test_malformed_committed_entry_raises_invalid_proof():
    """A garbage entry inside an otherwise valid attestation must fail
    with InvalidProof — not struct.error / UnicodeDecodeError / silent
    truncation — when verify_head decodes it (satellite regression:
    pre-fix this leaked struct.error)."""
    from repro.proof import Attestation
    good = encode_entry(b"k", "master", b"\x11" * 32)
    for garbage in (b"", b"\x01", b"\xff\xff\xff\xff",          # short kl
                    b"\x02\x00\x00\x00k",                        # short key
                    b"\x01\x00\x00\x00k\xff\xff\xff\xffx",       # short tag
                    b"\x01\x00\x00\x00k\x01\x00\x00\x00t\x00',"  # bad uid
                    b"\x01\x00\x00\x00k\x02\x00\x00\x00\xff\xfe"
                    + b"\x00" * 32):                             # bad utf8
        entries = sorted([good, garbage])
        leaves = entry_leaves(entries)
        att = Attestation(merkle_root(leaves), len(entries))
        proof = prove_entry(entries, leaves, garbage)
        with pytest.raises(InvalidProof):
            verify_head(att, proof.to_bytes())


# --------------------------------------------------- GC epoch handshake

def _head_chunks(db, uid):
    from repro.gc import mark
    live, _, missing = mark(db.store, [uid])
    assert missing == 0
    return live


def test_attested_head_survives_next_collection(db, rng):
    """THE orphaning race (ROADMAP): attest commits a head, the branch
    is retired, the next collection must NOT sweep the chunks beneath
    the freshly signed head — prove_member against it has to keep
    working until the second collection after the attest begins."""
    data = {b"e%03d" % i: rng.bytes(16) for i in range(120)}
    uid = db.put("k", FMap(data), "tmp")
    att = db.attest(secret=b"s")
    proof = db.prove_head("k", "tmp")
    db.remove("k", "tmp")                        # head retired post-attest
    rep1 = db.gc()                               # collection epoch 1
    # pre-fix: this collection swept the subgraph and the proofs dangle
    k, t, head = verify_head(att, proof, secret=b"s")
    assert head == uid
    mp = db.prove_member("k", uid=uid, item_key=b"e007")   # still servable
    from repro.proof import verify_member
    obj = db.get("k", uid=uid).obj
    assert verify_member(obj.data, mp).value == data[b"e007"]
    # the grace window is ONE epoch: the second collection reclaims
    rep2 = db.gc()
    assert rep2.swept_chunks > 0
    assert not db.store.has(uid)


def test_attested_head_survives_next_incremental_collection(db, rng):
    uid = db.put("k", FBlob(rng.bytes(20_000)), "tmp")
    db.attest()
    db.remove("k", "tmp")
    db.gc(incremental=True, budget=16)           # epoch 1: fenced
    assert db.get("k", uid=uid) is not None
    rep = db.gc(incremental=True, budget=16)     # epoch 2: reclaimed
    assert rep.swept_chunks > 0


def test_attest_mid_sweep_rescues_condemned_head(db, rng):
    """A head (re)established without a root barrier and then committed
    by an attestation issued MID-SWEEP must be rescued from the live
    condemned set (attest_fence), transitively."""
    from repro.gc import GCPhase
    data = rng.bytes(20_000)
    uid = db.put("k", FBlob(data), "tmp")
    db.remove("k", "tmp")                        # fully detached
    col = db.incremental_gc()
    while col.step(8) is GCPhase.MARK:
        pass
    assert col.phase is GCPhase.SWEEP            # condemned, none swept
    # a rogue/raw head re-establishment that fires NO root barrier:
    db.branches.set_head(b"k", "back", uid)
    db.attest(secret=b"s")                       # commits uid mid-sweep
    while col.step(8) is not GCPhase.DONE:
        pass
    assert db.get("k", "back").blob().read() == data


def test_attestation_context_carries_collector_epoch(db, rng):
    db.put("k", FBlob(b"v"))
    assert attestation_epoch(db.attest(context=b"app")) == 0
    db.gc()
    assert attestation_epoch(db.attest(context=b"app")) == 1
    db.gc(incremental=True, budget=8)
    assert attestation_epoch(db.attest()) == 2
    # foreign attestations without the tag read as None
    assert attestation_epoch(attest_heads(db.branches)) is None


def test_cluster_attestations_carry_cluster_epoch(rng):
    cl = Cluster(3, params=PARAMS)
    for i in range(6):
        cl.put("key%d" % i, FBlob(rng.bytes(500)))
    catt, atts = cl.attest(secret=b"s")
    assert attestation_epoch(catt) == 0
    assert all(attestation_epoch(a) == 0 for a in atts)
    cl.gc()
    catt, atts = cl.attest(secret=b"s")
    assert attestation_epoch(catt) == 1
    assert all(attestation_epoch(a) == 1 for a in atts)


def test_cluster_attested_head_survives_next_collection(rng):
    cl = Cluster(3, params=PARAMS)
    cl.put("key", FBlob(rng.bytes(9_000)), "tmp")
    svc = cl.servlet_of("key")
    uid = svc.branches.head(b"key", "tmp")
    cl.attest(secret=b"s")                       # pins every servlet head
    cl.remove("key", "tmp")
    cl.gc()                                      # epoch 1: fenced
    assert cl.get("key", uid=uid).blob().read() is not None
    rep = cl.gc()                                # epoch 2: reclaimed
    assert rep.swept_chunks > 0


def test_light_client_refreshes_anchor_from_attestation(rng):
    from repro.apps.blockchain import ForkBaseLedger, LightClient
    led = ForkBaseLedger()
    led.write("bank", "alice", b"10")
    led.commit()
    lc = LightClient(led.db.get("chain").uid)
    led.write("bank", "alice", b"20")
    led.commit()                                 # head moved on
    att = led.attest(secret=b"s")
    lc.refresh_head(att, led.prove_chain_head(), secret=b"s")
    assert lc.head_uid == led.db.get("chain").uid
    assert lc.attested_epoch == 0
    dist, val = lc.verify_state(led.prove_state("bank", "alice"),
                                "bank", "alice")
    assert val == b"20"
    # a proof for some other key cannot re-anchor the client
    with pytest.raises(InvalidProof):
        lc.refresh_head(att, led.db.prove_head("__l1__"), secret=b"s")


# ------------------------------------------------------------- caching

def test_prove_member_served_from_per_root_cache(db, rng):
    m = {b"k%03d" % i: rng.bytes(8) for i in range(200)}
    db.put("m", FMap(m))
    p1 = db.prove_member("m", item_key=b"k007")
    p2 = db.prove_member("m", item_key=b"k007")
    assert p2 is p1                              # resident, not re-walked
    assert db.proof_cache.hits == 1
    m[b"k007"] = b"new"
    db.put("m", FMap(m))                         # new root -> cold cache
    p3 = db.prove_member("m", item_key=b"k007")
    assert p3 is not p1
    from repro.proof import verify_member
    assert verify_member(db.get("m").obj.data,
                         p3.to_bytes()).value == b"new"
    # absence proofs share the cache
    a1 = db.prove_absence("m", item_key=b"zzz")
    assert db.prove_absence("m", item_key=b"zzz") is a1


def test_verify_memo_persists_across_rounds(db, rng):
    from repro.proof import prove_member as pm
    from repro.core.postree import POSTree
    db.put("m", FMap({b"k%04d" % i: rng.bytes(8) for i in range(400)}))
    obj = db.get("m").obj
    tree = POSTree.from_root(db.store, obj.type, obj.data, PARAMS)
    items = [(obj.data, pm(tree, pos=i * 7)) for i in range(30)]
    memo = VerifyMemo()
    verify_member_many(items, memo=memo)
    m1 = memo.misses
    assert m1 > 0 and memo.hits == 0
    verify_member_many(items, memo=memo)         # round 2: all resident
    assert memo.misses == m1
    assert memo.hits >= m1
    # forged proofs still fail under the memo
    import dataclasses
    bad = dataclasses.replace(items[0][1], value=b"forged")
    with pytest.raises(InvalidProof):
        verify_member_many([(items[0][0], bad)], memo=memo)


# ------------------------------------------------------------ audit daemon

def _mk_cluster(rng, n=3, keys=8):
    cl = Cluster(n, params=PARAMS)
    for i in range(keys):
        cl.put("key%d" % i, FMap({b"e%02d" % j: rng.bytes(12)
                                  for j in range(40)}))
    return cl


def test_daemon_backs_off_clean_nodes(rng):
    cl = _mk_cluster(rng)
    d = cl.audit_daemon(sample=64, secret=b"s", max_interval=16)
    for _ in range(60):
        rep = cl.audit_tick(budget=2)
        assert rep.ok
    # every target audited clean repeatedly -> intervals at the cap
    assert all(iv == 16 for iv in d._interval.values())
    # backoff means far fewer audits than (ticks x targets)
    assert d.audits < 60 * len(d._interval) / 2
    assert not d.quarantined


def test_daemon_quarantines_on_repeatable_finding(rng):
    cl = _mk_cluster(rng)
    d = cl.audit_daemon(sample=64, secret=b"s", max_interval=8)
    for _ in range(20):
        assert cl.audit_tick(budget=2).ok
    audits_before = d.audits
    # corrupt a head meta chunk on one node (heads are always checked)
    ni = next(i for i, nd in enumerate(cl.nodes)
              if nd.servlet.branches.keys())
    key = cl.nodes[ni].servlet.branches.keys()[0]
    uid = cl.nodes[ni].servlet.branches.head(key, "master")
    raw = cl.nodes[ni].store._data[uid]
    cl.nodes[ni].store._data[uid] = raw[:-1] + bytes([raw[-1] ^ 1])
    bad_tick = None
    for t in range(20):
        rep = cl.audit_tick(budget=2)
        if not rep.ok:
            bad_tick = t
            break
    assert bad_tick is not None
    assert f"node{ni}" in d.quarantined
    # the finding triggered an immediate re-audit (two audits that tick)
    assert d.audits >= audits_before + 2
    assert any(f.node == f"node{ni}" for f in d.findings)
    # repair + release: node re-enters rotation and audits clean again
    cl.nodes[ni].store._data[uid] = raw
    d.release(f"node{ni}")
    assert all(cl.audit_tick(budget=2).ok for _ in range(10))
    assert f"node{ni}" not in d.quarantined


def test_daemon_transient_finding_does_not_quarantine(rng):
    """A finding that vanishes on the immediate re-audit (read race,
    repaired replica) must not quarantine the node."""
    from repro.proof.audit import AuditDaemon
    cl = _mk_cluster(rng)
    d = AuditDaemon(cl, sample=64, secret=b"s")
    ni = 0
    while not cl.nodes[ni].servlet.branches.keys():
        ni += 1
    key = cl.nodes[ni].servlet.branches.keys()[0]
    uid = cl.nodes[ni].servlet.branches.head(key, "master")
    raw = cl.nodes[ni].store._data[uid]
    cl.nodes[ni].store._data[uid] = raw[:-1] + bytes([raw[-1] ^ 1])
    flipped = {"done": False}
    orig = d._audit_target

    def healing(target):
        rep = orig(target)
        if not rep.ok and not flipped["done"]:
            cl.nodes[ni].store._data[uid] = raw      # repaired in between
            flipped["done"] = True
        return rep

    d._audit_target = healing
    for _ in range(20):
        d.tick(budget=2)
    assert flipped["done"]                       # the finding did surface
    assert not d.quarantined                     # but did not stick


def test_daemon_covers_placement(rng):
    """The master-index placement check is its own backoff target: a
    chunk lost by its owning node is found without any engine audit."""
    from repro.proof.audit import AuditDaemon
    cl = _mk_cluster(rng)
    d = AuditDaemon(cl, sample=10_000, secret=b"s")
    cid, ni = next(iter(cl.index.items()))
    del cl.nodes[ni].store._data[cid]            # node silently lost it
    seen = []
    for _ in range(12):
        seen.extend(d.tick(budget=4).findings)
    assert any(f.kind == "missing" and f.cid == cid for f in seen)
    assert f"node{ni}" in d.quarantined
