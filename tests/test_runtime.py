"""Async cluster runtime: coalesced dispatch, backpressure/admission,
maintenance daemon, quarantine enforcement, and the typed routing-miss
regression.

Determinism: most tests drive the runtime with the synchronous
``drain()`` dispatcher; the threaded interleaving tests (a fast one in
tier 1, a big slow-marked one for the scheduled ``runtime-race`` CI
job) run real writer threads against the worker/daemon threads and
check the same invariants as ``test_gc_concurrent``: no head ever
dangles, the master index never lies, and GC after the dust settles
sweeps without eating a live chunk.
"""
import threading

import numpy as np
import pytest

from repro import obs
from repro.core import (Backpressure, Cluster, FBlob, GuardFailed,
                        MaintenanceDaemon, RoutingIndexMiss,
                        RuntimeConfig)
from repro.storage.backend import ChunkMissing


@pytest.fixture(autouse=True)
def fresh_obs():
    obs.reset()
    obs.enable()
    yield
    obs.reset()
    obs.enable()


def _blob(rng, n=2048):
    return FBlob(rng.bytes(n))


# ------------------------------------------------------ coalesced dispatch

def test_coalesced_puts_match_sequential_semantics(rng):
    cl = Cluster(4)
    rt = cl.runtime()
    futs = {}
    for i in range(12):
        futs[f"k{i}"] = rt.submit_put(f"k{i}", _blob(rng))
    # same-key chain inside ONE batch: three more versions of k0
    chain = [rt.submit_put("k0", _blob(rng)) for _ in range(3)]
    assert rt.drain() == 15
    for key, f in futs.items():
        assert f.done()
        if key != "k0":
            assert cl.get(key).uid == f.result()
    # k0's head is the LAST queued put and its history is the chain
    assert cl.get("k0").uid == chain[-1].result()
    uids = [o.uid for o in cl.track("k0", "master")]
    assert uids == [chain[2].result(), chain[1].result(),
                    chain[0].result(), futs["k0"].result()]


def test_put_then_get_ordering_within_queue(rng):
    cl = Cluster(3)
    rt = cl.runtime()
    blob = _blob(rng)
    pf = rt.submit_put("ordered", blob)
    gf = rt.submit_get("ordered")
    rt.drain()
    assert gf.result() is not None
    assert gf.result().uid == pf.result()


def test_coalescing_is_fewer_store_batches(rng):
    """The point of the runtime: N requests cost ~O(nodes) routing
    put batches, not O(N)."""
    cl = Cluster(4)
    before = sum(n.servlet.store.stats.put_batches for n in cl.nodes)
    rt = cl.runtime()
    for i in range(32):
        rt.submit_put(f"bulk{i}", _blob(rng, 512))
    rt.drain()
    batched = (sum(n.servlet.store.stats.put_batches for n in cl.nodes)
               - before)
    cl2 = Cluster(4)
    before2 = sum(n.servlet.store.stats.put_batches for n in cl2.nodes)
    rng2 = np.random.default_rng(0)
    for i in range(32):
        cl2.put(f"bulk{i}", _blob(rng2, 512))
    single = (sum(n.servlet.store.stats.put_batches for n in cl2.nodes)
              - before2)
    assert batched < single


def test_get_batch_verbatim_and_missing(rng):
    cl = Cluster(3)
    rt = cl.runtime()
    blob = rng.bytes(4096)
    cl.put("present", FBlob(blob))
    g1 = rt.submit_get("present")
    g2 = rt.submit_get("never-written")
    rt.drain()
    assert g1.result().blob().read() == blob
    assert g2.result() is None


def test_guard_failure_does_not_poison_batch(rng):
    cl = Cluster(2)
    rt = cl.runtime()
    u0 = cl.put("guarded", _blob(rng))
    ok = rt.submit_put("plain", _blob(rng))
    bad = rt.submit_put("guarded", _blob(rng), guard_uid=b"\x00" * 32)
    good = rt.submit_put("guarded", _blob(rng), guard_uid=u0)
    rt.drain()
    assert ok.result()
    with pytest.raises(GuardFailed):
        bad.result()
    assert cl.get("guarded").uid == good.result()


# --------------------------------------------------- backpressure/admission

def test_backpressure_bounds_each_servlet_queue(rng):
    cl = Cluster(1)          # one servlet: every key shares the queue
    rt = cl.runtime(RuntimeConfig(queue_depth=4))
    for i in range(4):
        rt.submit_put(f"bp{i}", _blob(rng, 256))
    with pytest.raises(Backpressure) as ei:
        rt.submit_put("bp-overflow", _blob(rng, 256))
    assert ei.value.depth == 4 and ei.value.bound == 4
    assert obs.counter("runtime_backpressure_total").value == 1
    rt.drain()               # queue drains -> admission reopens
    rt.submit_put("bp-after", _blob(rng, 256))
    rt.drain()
    assert cl.get("bp-after") is not None


def test_admission_tightens_on_windowed_store_p99(rng):
    cl = Cluster(2)
    cfg = RuntimeConfig(queue_depth=64, max_batch=16,
                        admission_p99_us=1000.0)
    rt = cl.runtime(cfg)
    assert rt.admission.bound() == 64 and rt.admission.batch() == 16
    # a slow window: the routing store's put histogram jumps
    h = obs.REGISTRY.histogram("store_put_us", {"backend": "routing"})
    for _ in range(8):
        h.observe(0.05)               # 50 ms ≫ the 1 ms threshold
    assert rt.admission.update() is True
    assert rt.admission.bound() == 32 and rt.admission.batch() == 8
    assert obs.EVENTS.counts().get("runtime.congested", 0) == 1
    # a quiet window (no new samples) clears the verdict
    assert rt.admission.update() is False
    assert rt.admission.bound() == 64


def test_admission_reads_fresh_slow_spans(rng):
    cl = Cluster(2)
    rt = cl.runtime(RuntimeConfig(slow_span_us=10_000.0))
    with obs.trace("store.put", backend="routing") as sp:
        pass
    # forge the duration (we cannot sleep 10ms+ in a unit test); the
    # span is already in recent_spans with a fresh monotonic start
    sp.start_s = obs.monotonic()
    sp.duration_s = 0.5
    assert rt.admission.update() is True
    rt.admission.update()
    assert rt.admission.congested is False   # span is no longer fresh


# ------------------------------------------------------- typed routing miss

def test_routing_index_miss_is_typed(rng):
    """Regression: a master-index miss used to silently fall back to
    the hash owner — which holds no copy — so the read failed from the
    WRONG node with a generic miss."""
    cl = Cluster(3)
    store = cl.nodes[0].servlet.store
    ghost = bytes(32)
    with pytest.raises(RoutingIndexMiss) as ei:
        store.get_many([ghost])
    assert ei.value.cid == ghost
    assert isinstance(ei.value, ChunkMissing)     # still a KeyError
    assert "master-index" in str(ei.value)
    # membership and delete stay lenient: absent, not an error
    assert store.has_many([ghost]) == [False]
    assert store.delete_many([ghost]) == 0


def test_iter_cids_scoped_to_home_node_and_lazy(rng):
    cl = Cluster(4)
    for i in range(16):
        cl.put(f"scope{i}", _blob(rng))
    shares = []
    for ni, nd in enumerate(cl.nodes):
        it = nd.servlet.store.iter_cids()
        assert iter(it) is it, "inventory must stream, not materialize"
        share = set(it)
        owned = {cid for cid, n in cl.index.items() if n == ni}
        assert share == owned, "servlet inventory == its index share"
        shares.append(share)
    union = set().union(*shares)
    assert union == set(cl.index)
    for a in range(len(shares)):
        for b in range(a + 1, len(shares)):
            assert not (shares[a] & shares[b])


# --------------------------------------------------- quarantine enforcement

def test_quarantine_enforced_and_rereplicated(rng):
    cl = Cluster(4)
    for i in range(24):
        cl.put(f"q{i}", _blob(rng))
    victim = max(range(4), key=lambda ni: cl.nodes[ni].stats.chunks)
    had = len(cl.nodes[victim].store)
    assert had > 0
    queued = cl.quarantine_node(victim, reason="test-corruption")
    assert queued == had
    # 1) placement routes around the node: NO new chunk lands there
    before = len(cl.nodes[victim].store)
    for i in range(16):
        cl.put(f"post-q{i}", _blob(rng))
    assert len(cl.nodes[victim].store) == before
    assert all(n != victim
               for cid, n in cl.index.items()
               if cid not in set(cl.nodes[victim].store.iter_cids()))
    # 2) re-replication drains the backlog and restores availability
    assert cl.rereplicate() >= queued
    assert cl.rerep_backlog() == 0
    assert len(cl.nodes[victim].store) == 0
    assert cl.rerep_lost == 0
    assert victim not in set(cl.index.values())
    for i in range(24):
        assert cl.get(f"q{i}") is not None        # every read survives
    # 3) release: the node rejoins placement
    cl.release_node(victim)
    for i in range(32):
        cl.put(f"post-r{i}", _blob(rng))
    assert len(cl.nodes[victim].store) > 0


def test_rereplication_drops_corrupt_copies_honestly(rng):
    cl = Cluster(3)
    for i in range(12):
        cl.put(f"c{i}", _blob(rng))
    victim = max(range(3), key=lambda ni: cl.nodes[ni].stats.chunks)
    # corrupt one chunk ON the victim before quarantining it
    cid = next(iter(cl.nodes[victim].store.iter_cids()))
    cl.nodes[victim].store._data[cid] = b"garbage-bytes"
    cl.quarantine_node(victim, reason="corrupt")
    cl.rereplicate()
    assert cl.rerep_lost == 1
    assert cid not in cl.index          # honest: typed miss, not bad bytes
    with pytest.raises(RoutingIndexMiss):
        cl.nodes[0].servlet.store.get_many([cid])


def test_audit_daemon_quarantine_reaches_routing_layer(monkeypatch):
    """audit.quarantine/audit.release findings ENFORCE, not just
    report: the daemon's direct hook calls flip Cluster.quarantined
    (so this works with REPRO_OBS=0 too)."""
    from repro.proof.audit import AuditDaemon, AuditFinding, AuditReport
    rng = np.random.default_rng(1)
    cl = Cluster(2)
    for i in range(8):
        cl.put(f"a{i}", _blob(rng))
    daemon = AuditDaemon(cl, sample=4)
    monkeypatch.setattr(
        daemon, "_audit_target",
        lambda target: AuditReport(findings=[
            AuditFinding("node1", "corrupt", "injected")]))
    daemon.tick()
    assert "node1" in daemon.quarantined
    assert cl.quarantined == {1}                  # ENFORCED
    assert cl.rerep_backlog() == cl.nodes[1].stats.chunks \
        or cl.rerep_backlog() > 0 or cl.nodes[1].stats.chunks == 0
    cl.rereplicate()
    assert len(cl.nodes[1].store) == 0
    daemon.release("node1")
    assert cl.quarantined == set()                # release enforced too


def test_audit_daemon_quarantine_enforced_with_obs_disabled(monkeypatch):
    from repro.proof.audit import AuditDaemon, AuditFinding, AuditReport
    rng = np.random.default_rng(2)
    cl = Cluster(2)
    for i in range(6):
        cl.put(f"d{i}", _blob(rng))
    obs.disable()
    try:
        daemon = AuditDaemon(cl, sample=4)
        monkeypatch.setattr(
            daemon, "_audit_target",
            lambda target: AuditReport(findings=[
                AuditFinding("node0", "missing", "injected")]))
        daemon.tick()
        assert cl.quarantined == {0}
        assert not obs.EVENTS.events("audit.quarantine")  # no journal...
        cl.rereplicate()                                  # ...but enforced
        assert len(cl.nodes[0].store) == 0
    finally:
        obs.enable()


# ------------------------------------------------------- maintenance daemon

def test_daemon_shares_one_budget_rerep_first(rng):
    cl = Cluster(3)
    for i in range(18):
        cl.put(f"m{i}", _blob(rng))
    victim = max(range(3), key=lambda ni: cl.nodes[ni].stats.chunks)
    queued = cl.quarantine_node(victim)
    d = MaintenanceDaemon(cl, config=RuntimeConfig(tick_budget=4,
                                                   audit_every=1000))
    rep = d.tick()
    assert rep["rerep"] == 4 and rep["budget"] == 4
    total = rep["rerep"]
    while cl.rerep_backlog():
        total += d.tick()["rerep"]
    assert total == queued
    assert len(cl.nodes[victim].store) == 0


def test_daemon_backs_off_under_foreground_load(rng):
    cl = Cluster(2)
    rt = cl.runtime(RuntimeConfig(queue_depth=64))
    cfg = RuntimeConfig(tick_budget=64, backoff_queued=2,
                        fold_every=1, compact_every=1)
    d = MaintenanceDaemon(cl, runtime=rt, config=cfg)
    for i in range(6):                 # queued, NOT drained: deep queue
        rt.submit_put(f"fg{i}", _blob(rng, 256))
    rep = d.tick()
    assert rep["backoff"] is True
    assert rep["budget"] == 16         # quarter budget
    assert rep["folds"] == 0 and rep["compactions"] == 0
    rt.drain()
    rep = d.tick()
    assert rep["backoff"] is False
    assert rep["folds"] == 1 and rep["compactions"] == 1


def test_daemon_staggers_folds_and_runs_gc_cycles(rng):
    cl = Cluster(2)
    # dirty live tables on both servlets
    for i in range(4):
        t = cl.live(f"lv{i}")
        t.put(b"f", rng.bytes(64))
    # garbage to collect: forked-then-removed branches (overwrites alone
    # stay reachable through version lineage)
    for i in range(4):
        cl.put(f"g{i}", _blob(rng))
        cl.fork(f"g{i}", "master", "tmp")
        cl.put(f"g{i}", _blob(rng), "tmp")
        cl.remove(f"g{i}", "tmp")
    cfg = RuntimeConfig(fold_every=1, audit_every=1000,
                        compact_every=1000, gc_cycle_ticks=2,
                        tick_budget=64)
    d = MaintenanceDaemon(cl, config=cfg)
    folds = 0
    for _ in range(40):
        folds += d.tick()["folds"]
        if (d.collector is not None and not d.collector.active
                and not any(t.dirty_count for t in
                            [cl.live(f"lv{i}") for i in range(4)])):
            break
    assert folds >= 2                  # round-robined across servlets
    assert d.collector is not None and not d.collector.active
    assert d.collector.report.swept_chunks > 0
    for i in range(4):                 # folded live state survives GC
        assert cl.live(f"lv{i}").get(b"f") is not None
        assert cl.get(f"g{i}") is not None


# ----------------------------------------------------- threaded interleaving

def _stress(n_nodes, writers, puts_each, rng, *, quarantine_mid=False,
            cfg=None):
    cl = Cluster(n_nodes)
    cfg = cfg or RuntimeConfig(queue_depth=4096, gc_cycle_ticks=3,
                               tick_interval_s=0.001, fold_every=2,
                               audit_every=3)
    rt = cl.runtime(cfg).start(daemon=True)
    errors: list = []
    results: dict[str, bytes] = {}
    lock = threading.Lock()

    def writer(w):
        r = np.random.default_rng(1000 + w)
        for i in range(puts_each):
            key = f"w{w}-k{i % 8}"       # 8 keys per writer, re-put often
            try:
                f = rt.submit_put(key, FBlob(r.bytes(1024)))
                uid = f.result(timeout=30)
                with lock:
                    results[key] = uid   # this writer's latest uid
            except Exception as e:       # noqa: BLE001
                errors.append((key, e))

    threads = [threading.Thread(target=writer, args=(w,))
               for w in range(writers)]
    for t in threads:
        t.start()
    if quarantine_mid:
        for t in threads:
            t.join(timeout=0.05)
        cl.quarantine_node(0, reason="mid-stress")
    for t in threads:
        t.join(timeout=60)
    rt.stop()
    assert not errors, errors[:3]
    # invariant 1: every key's head is this writer's LAST uid and reads
    for key, uid in results.items():
        h = cl.get(key)
        assert h is not None and h.uid == uid
        assert h.blob().read()
    # invariant 2: the master index never lies (placement audit clean,
    # modulo the quarantined node whose chunks may still await rerep)
    cl.rereplicate()
    from repro.proof.audit import Auditor
    rep = Auditor(sample=64).audit_placement(cl)
    assert rep.ok, str(rep)
    # invariant 3: a full GC after the dust settles never eats a head
    cl.gc()
    for key, uid in results.items():
        assert cl.get(key).uid == uid
    if quarantine_mid:
        assert 0 not in set(cl.index.values())
        assert len(cl.nodes[0].store) == 0
    return cl


def test_threaded_writers_with_daemon_small(rng):
    _stress(3, writers=3, puts_each=12, rng=rng)


def test_threaded_quarantine_mid_stress_small(rng):
    _stress(3, writers=3, puts_each=12, rng=rng, quarantine_mid=True)


@pytest.mark.slow
def test_threaded_writers_with_daemon_race(rng):
    """Scheduled runtime-race job: heavy interleaving of writers,
    dispatcher workers, GC slices, audits, folds and re-replication."""
    _stress(4, writers=8, puts_each=80, rng=rng)


@pytest.mark.slow
def test_threaded_quarantine_race(rng):
    _stress(4, writers=8, puts_each=60, rng=rng, quarantine_mid=True)
