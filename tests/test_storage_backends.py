"""Backend conformance: one shared put/get/has/dedup/stats suite over
every StorageBackend implementation (memory, log, LRU, replicated,
sharded, cluster routing), plus the batched-pipeline invariants:
a value with N chunks commits via one put_many batch, and the
vectorized fphash path matches the per-chunk kernel bit-for-bit."""
import numpy as np
import pytest

from repro.core import Cluster, ForkBase, FBlob, FMap
from repro.core.chunk import cid_of, encode_chunk
from repro.storage import (ChunkMissing, LRUCacheBackend, MemoryBackend,
                           ReplicatedBackend, ShardedBackend, StorageBackend,
                           WriteBuffer, make_backend)

BACKENDS = ["memory", "log", "lru", "replicated", "sharded", "routing"]


@pytest.fixture
def backend(request, tmp_path):
    name = request.param
    if name == "memory":
        return MemoryBackend()
    if name == "log":
        return MemoryBackend(log_path=str(tmp_path / "chunks.log"))
    if name == "lru":
        return LRUCacheBackend(MemoryBackend(), capacity_bytes=1 << 20)
    if name == "replicated":
        return ReplicatedBackend([MemoryBackend() for _ in range(3)], k=2)
    if name == "sharded":
        return ShardedBackend(4)
    if name == "routing":
        return Cluster(3).nodes[0].servlet.store
    raise AssertionError(name)


def chunks(rng, n=24, size=400):
    return [encode_chunk(3, rng.bytes(size) + bytes([i])) for i in range(n)]


all_backends = pytest.mark.parametrize("backend", BACKENDS, indirect=True)


@all_backends
def test_satisfies_protocol(backend):
    assert isinstance(backend, StorageBackend)


@all_backends
def test_put_get_roundtrip_singular(backend, rng):
    raw = encode_chunk(3, rng.bytes(1000))
    cid = backend.put(raw)
    assert cid == cid_of(raw)
    assert backend.get(cid) == raw
    assert backend.has(cid)


@all_backends
def test_batched_roundtrip_preserves_order(backend, rng):
    raws = chunks(rng)
    cids = backend.put_many(raws)
    assert cids == [cid_of(r) for r in raws]
    assert backend.get_many(cids) == raws
    assert backend.get_many(list(reversed(cids))) == list(reversed(raws))
    assert all(backend.has_many(cids))


@all_backends
def test_explicit_cids_accepted(backend, rng):
    raws = chunks(rng, n=5)
    pre = [cid_of(r) for r in raws]
    assert backend.put_many(raws, pre) == pre
    assert backend.get_many(pre) == raws


@all_backends
def test_missing_chunk_raises(backend, rng):
    backend.put_many(chunks(rng, n=3))
    ghost = bytes(32)
    assert backend.has_many([ghost]) == [False]
    with pytest.raises(KeyError):        # ChunkMissing subclasses KeyError
        backend.get(ghost)


@all_backends
def test_dedup_on_put(backend, rng):
    raw = encode_chunk(3, rng.bytes(2000))
    backend.put(raw)
    phys = backend.stats.physical_bytes
    backend.put(raw)
    backend.put_many([raw, raw])
    st = backend.stats
    assert st.physical_bytes == phys          # stored once (k copies max)
    assert st.dedup_hits >= 3
    assert st.logical_bytes == 4 * len(raw)
    k = getattr(backend, "k", 1)              # replication is physical
    assert st.dedup_ratio > 3.9 / k


@all_backends
def test_len_counts_distinct_chunks(backend, rng):
    raws = chunks(rng, n=10)
    backend.put_many(raws + raws[:4])
    assert len(backend) == 10


@all_backends
def test_stats_count_batches(backend, rng):
    raws = chunks(rng, n=16)
    cids = backend.put_many(raws)
    backend.get_many(cids)
    st = backend.stats
    assert st.puts == 16 and st.put_batches == 1
    assert st.gets == 16 and st.get_batches == 1


@all_backends
def test_flush_is_safe(backend, rng):
    cid = backend.put(encode_chunk(3, rng.bytes(100)))
    backend.flush()
    assert backend.get(cid)


# ------------------------------------------------------- batched pipeline

@pytest.mark.parametrize("backend", ["memory"], indirect=True)
def test_value_commits_in_one_batch(backend, rng):
    """Acceptance: N-chunk value -> one put_many (batch calls << chunks)."""
    db = ForkBase(backend)
    db.put("blob", FBlob(rng.bytes(300_000)))
    st = backend.stats
    assert st.put_batches == 1
    assert st.puts > 20 * st.put_batches
    db.put("map", FMap({b"k%04d" % i: rng.bytes(64) for i in range(3000)}))
    assert st.put_batches == 2
    assert st.puts > 20 * st.put_batches


@pytest.mark.parametrize("backend", ["memory"], indirect=True)
def test_write_buffer_nests_and_passes_through(backend, rng):
    outer = WriteBuffer(backend)
    inner = WriteBuffer(outer)
    raws = chunks(rng, n=6)
    cids = inner.put_many(raws)
    assert inner.get_many(cids) == raws       # reads see pending chunks
    assert len(backend) == 0
    inner.flush()
    assert len(backend) == 0                  # still buffered in outer
    outer.flush()
    assert backend.stats.put_batches == 1     # ONE real store round-trip
    assert backend.get_many(cids) == raws
    # closed buffers are transparent: writes land directly in the store
    extra = inner.put(encode_chunk(3, rng.bytes(50)))
    assert backend.has(extra)


@pytest.mark.parametrize("backend", ["lru"], indirect=True)
def test_lru_serves_repeat_reads_from_cache(backend, rng):
    cids = backend.put_many(chunks(rng, n=8))
    backend.inner.stats.gets = 0
    backend.get_many(cids)
    backend.get_many(cids)
    assert backend.inner.stats.gets == 0      # write-through populated it
    assert backend.stats.cache_hits == 16


@pytest.mark.parametrize("backend", ["replicated"], indirect=True)
def test_replicated_reads_stay_batched(backend, rng):
    """get_many groups by primary replica: O(replicas) inner batches,
    not one batch-of-one per cid."""
    raws = chunks(rng, n=30)
    cids = backend.put_many(raws)
    g0 = sum(s.stats.get_batches for s in backend.stores)
    assert backend.get_many(cids) == raws
    assert sum(s.stats.get_batches for s in backend.stores) - g0 <= \
        len(backend.stores)


@pytest.mark.parametrize("backend", ["replicated"], indirect=True)
def test_replication_factor_and_failover(backend, rng):
    raw = encode_chunk(3, rng.bytes(1500))
    cid = backend.put(raw)
    assert sum(1 for s in backend.stores if s.has(cid)) == backend.k
    for s in backend.stores:                  # kill the primary replica
        if s.has(cid):
            del s._data[cid]
            break
    assert backend.get(cid) == raw            # failover to the other copy
    with pytest.raises(ChunkMissing):
        backend.get_many([bytes(32)])


@pytest.mark.parametrize("backend", ["sharded"], indirect=True)
def test_sharding_spreads_chunks(backend, rng):
    backend.put_many(chunks(rng, n=200))
    dist = [len(s) for s in backend.shards]
    assert sum(dist) == 200
    assert min(dist) > 0                      # cid hash spreads uniformly


@pytest.mark.parametrize("backend", ["memory"], indirect=True)
def test_make_backend_specs(backend, tmp_path, rng):
    for spec, kw in [("memory", {}), ("lru+memory", {}),
                     ("lru+sharded", {"shards": 2}),
                     ("replicated", {"n": 3, "k": 2}),
                     ("log", {"log_path": str(tmp_path / "l.log")})]:
        b = make_backend(spec, **kw)
        raw = encode_chunk(3, rng.bytes(128))
        assert b.get(b.put(raw)) == raw
    with pytest.raises(ValueError):
        make_backend("bogus")


@pytest.mark.parametrize("backend", ["memory"], indirect=True)
def test_fphash_many_matches_per_chunk_kernel(backend, rng):
    from repro.kernels.fphash import fphash, fphash_many
    blobs = [rng.bytes(n) for n in (0, 1, 300, 4096, 4097, 9000)]
    assert fphash_many(blobs) == [fphash(b) for b in blobs]


@pytest.mark.parametrize("backend", ["memory"], indirect=True)
def test_fphash_dispatch_roundtrip(backend, rng):
    """use_fphash(): cids route through the batched Pallas kernel; the
    engine works identically (one launch per value commit)."""
    from repro.core import hashing
    hashing.use_fphash()
    try:
        db = ForkBase(backend)
        data = rng.bytes(50_000)
        db.put("k", FBlob(data))
        assert db.get("k").blob().read() == data
        assert backend.stats.put_batches == 1
    finally:
        hashing.use_sha256()
