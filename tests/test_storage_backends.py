"""Backend conformance: one shared put/get/has/delete/dedup/stats suite
over every StorageBackend implementation (memory, log, LRU, replicated,
sharded, cluster routing, durable segment, tiered), plus the
batched-pipeline invariants: a value with N chunks commits via one
put_many batch, and the vectorized fphash path matches the per-chunk
kernel bit-for-bit.  The delete/GC cases cover the sweep verb added for
garbage collection: chunks leave every replica/shard/cache coherently
and stats shrink."""
import pytest

from repro.core import Cluster, ForkBase, FBlob, FMap
from repro.core.chunk import cid_of, encode_chunk
from repro.storage import (ChunkMissing, LRUCacheBackend, MemoryBackend,
                           ReplicatedBackend, SegmentBackend, ShardedBackend,
                           StorageBackend, TamperedChunk, TieredBackend,
                           WriteBuffer, make_backend)

BACKENDS = ["memory", "log", "lru", "replicated", "sharded", "routing",
            "segment", "tiered"]


@pytest.fixture
def backend(request, tmp_path):
    name = request.param
    if name == "memory":
        return MemoryBackend()
    if name == "log":
        return MemoryBackend(log_path=str(tmp_path / "chunks.log"))
    if name == "lru":
        return LRUCacheBackend(MemoryBackend(), capacity_bytes=1 << 20)
    if name == "replicated":
        return ReplicatedBackend([MemoryBackend() for _ in range(3)], k=2)
    if name == "sharded":
        return ShardedBackend(4)
    if name == "routing":
        return Cluster(3).nodes[0].servlet.store
    # small segments / hot tier so multi-segment sealing, demotion and
    # promotion all run inside the shared suite
    if name == "segment":
        return SegmentBackend(str(tmp_path / "segs"), segment_bytes=8 << 10)
    if name == "tiered":
        return TieredBackend(
            SegmentBackend(str(tmp_path / "cold"), segment_bytes=8 << 10),
            hot_bytes=16 << 10)
    raise AssertionError(name)


def chunks(rng, n=24, size=400):
    return [encode_chunk(3, rng.bytes(size) + bytes([i])) for i in range(n)]


all_backends = pytest.mark.parametrize("backend", BACKENDS, indirect=True)


@all_backends
def test_satisfies_protocol(backend):
    assert isinstance(backend, StorageBackend)


@all_backends
def test_put_get_roundtrip_singular(backend, rng):
    raw = encode_chunk(3, rng.bytes(1000))
    cid = backend.put(raw)
    assert cid == cid_of(raw)
    assert backend.get(cid) == raw
    assert backend.has(cid)


@all_backends
def test_batched_roundtrip_preserves_order(backend, rng):
    raws = chunks(rng)
    cids = backend.put_many(raws)
    assert cids == [cid_of(r) for r in raws]
    assert backend.get_many(cids) == raws
    assert backend.get_many(list(reversed(cids))) == list(reversed(raws))
    assert all(backend.has_many(cids))


@all_backends
def test_explicit_cids_accepted(backend, rng):
    raws = chunks(rng, n=5)
    pre = [cid_of(r) for r in raws]
    assert backend.put_many(raws, pre) == pre
    assert backend.get_many(pre) == raws


@all_backends
def test_missing_chunk_raises(backend, rng):
    backend.put_many(chunks(rng, n=3))
    ghost = bytes(32)
    assert backend.has_many([ghost]) == [False]
    with pytest.raises(KeyError):        # ChunkMissing subclasses KeyError
        backend.get(ghost)


@all_backends
def test_dedup_on_put(backend, rng):
    raw = encode_chunk(3, rng.bytes(2000))
    backend.put(raw)
    phys = backend.stats.physical_bytes
    backend.put(raw)
    backend.put_many([raw, raw])
    st = backend.stats
    assert st.physical_bytes == phys          # stored once (k copies max)
    assert st.dedup_hits >= 3
    assert st.logical_bytes == 4 * len(raw)
    k = getattr(backend, "k", 1)              # replication is physical
    assert st.dedup_ratio > 3.9 / k


@all_backends
def test_len_counts_distinct_chunks(backend, rng):
    raws = chunks(rng, n=10)
    backend.put_many(raws + raws[:4])
    assert len(backend) == 10


@all_backends
def test_stats_count_batches(backend, rng):
    raws = chunks(rng, n=16)
    cids = backend.put_many(raws)
    backend.get_many(cids)
    st = backend.stats
    assert st.puts == 16 and st.put_batches == 1
    assert st.gets == 16 and st.get_batches == 1


@all_backends
def test_flush_is_safe(backend, rng):
    cid = backend.put(encode_chunk(3, rng.bytes(100)))
    backend.flush()
    assert backend.get(cid)


# --------------------------------------------------------- delete (GC sweep)

@all_backends
def test_delete_many_removes_everywhere(backend, rng):
    raws = chunks(rng, n=12)
    cids = backend.put_many(raws)
    phys = _physical_bytes(backend)
    assert backend.delete_many(cids[:5]) == 5
    assert backend.has_many(cids) == [False] * 5 + [True] * 7
    with pytest.raises(KeyError):
        backend.get(cids[0])
    assert len(backend) == 7
    st = backend.stats
    assert st.deletes == 5
    assert st.reclaimed_bytes > 0
    assert 0 <= _physical_bytes(backend) < phys
    assert backend.get_many(cids[5:]) == raws[5:]   # survivors intact


@all_backends
def test_delete_missing_is_noop(backend, rng):
    cid = backend.put(encode_chunk(3, rng.bytes(64)))
    assert backend.delete_many([bytes(32)]) == 0
    assert backend.stats.deletes == 0
    assert backend.get(cid)


@all_backends
def test_reput_after_delete(backend, rng):
    raw = encode_chunk(3, rng.bytes(500))
    cid = backend.put(raw)
    backend.delete(cid)
    d0 = backend.stats.dedup_hits
    assert backend.put(raw) == cid                  # fresh put, not dedup
    assert backend.stats.dedup_hits == d0
    assert backend.get(cid) == raw


@all_backends
def test_iter_cids_is_sweep_inventory(backend, rng):
    raws = chunks(rng, n=9)
    cids = backend.put_many(raws)
    assert _inventory(backend) == set(cids)
    backend.delete_many(cids[:4])
    assert _inventory(backend) == set(cids[4:])


def _inventory(backend):
    """Cluster-wide sweep inventory.  A routing store's ``iter_cids``
    is scoped to its OWN servlet's share (lazy, per-node) — the full
    inventory is the union across servlets, and the shares must be
    disjoint (each chunk swept exactly once in a cluster-wide walk)."""
    cl = getattr(backend, "cluster", None)
    if cl is None:
        return set(backend.iter_cids())
    shares = [set(n.servlet.store.iter_cids()) for n in cl.nodes]
    union: set = set()
    for s in shares:
        assert not (union & s), "servlet inventories must be disjoint"
        union |= s
    return union


def _physical_bytes(backend):
    """Physical truth for a stack: cluster routing stores are write-side
    views, so sum the node stores instead."""
    cl = getattr(backend, "cluster", None)
    if cl is not None:
        return sum(n.store.stats.physical_bytes for n in cl.nodes)
    return backend.stats.physical_bytes


@all_backends
def test_gc_collects_removed_branch_through_stack(backend, rng):
    """Acceptance: two branches, remove one, collect; the store shrinks
    and the surviving head reads back byte-identical — through every
    backend stack (memory/log/LRU/replicated/sharded/cluster routing)."""
    db = ForkBase(backend)
    keep = rng.bytes(60_000)
    db.put("k", FBlob(keep))
    db.fork("k", "master", "scratch")
    db.put("k", FBlob(rng.bytes(60_000)), "scratch")
    n0 = len(backend)
    phys0 = _physical_bytes(backend)
    db.remove("k", "scratch")
    report = db.gc()
    assert report.swept_chunks > 0
    assert len(backend) < n0
    assert 0 <= _physical_bytes(backend) < phys0
    assert db.get("k").blob().read() == keep
    # idempotent: a second collect sweeps nothing
    assert db.gc().swept_chunks == 0


@pytest.mark.parametrize("backend", ["replicated"], indirect=True)
def test_delete_removes_all_replicas(backend, rng):
    raw = encode_chunk(3, rng.bytes(900))
    cid = backend.put(raw)
    assert sum(1 for s in backend.stores if s.has(cid)) == backend.k
    assert backend.delete(cid) == 1
    assert not any(s.has(cid) for s in backend.stores)
    assert backend.stats.deletes == 1               # counted once, not k


@pytest.mark.parametrize("backend", ["lru"], indirect=True)
def test_delete_invalidates_cache(backend, rng):
    cid = backend.put(encode_chunk(3, rng.bytes(700)))
    backend.get(cid)                                # hot in cache
    backend.delete(cid)
    assert not backend.has(cid)
    with pytest.raises(ChunkMissing):
        backend.get(cid)                            # not served from LRU


@pytest.mark.parametrize("backend", ["routing"], indirect=True)
def test_cluster_delete_updates_index_and_node_stats(backend, rng):
    cl = backend.cluster
    cids = backend.put_many(chunks(rng, n=40))
    bytes0 = sum(n.stats.chunk_bytes for n in cl.nodes)
    backend.delete_many(cids[:15])
    assert all(c not in cl.index for c in cids[:15])
    assert sum(n.stats.chunks for n in cl.nodes) == 25
    assert sum(n.stats.chunk_bytes for n in cl.nodes) < bytes0


def test_write_buffer_delete_counts_pending_and_inner_once(rng):
    """A cid both pending and already stored inner is ONE logical chunk."""
    inner = MemoryBackend()
    raw = encode_chunk(3, rng.bytes(200))
    cid = inner.put(raw)
    buf = WriteBuffer(inner)
    buf.put(raw)                                    # pending duplicate
    assert buf.delete_many([cid, cid]) == 1
    assert not inner.has(cid) and not buf.has(cid)


def test_write_buffer_delete_retracts_pending(rng):
    inner = MemoryBackend()
    buf = WriteBuffer(inner)
    raws = chunks(rng, n=4)
    cids = buf.put_many(raws)
    buf.delete_many(cids[:2])                       # never reach the store
    assert buf.has_many(cids) == [False, False, True, True]
    buf.flush()
    assert len(inner) == 2
    assert inner.get_many(cids[2:]) == raws[2:]
    # closed buffer: transparent pass-through
    assert buf.delete_many([cids[2]]) == 1
    assert not inner.has(cids[2])


# ------------------------------------------- write barrier (incremental GC)


@all_backends
def test_put_listener_fires_with_batch_cids(backend, rng):
    """Conformance: every backend notifies put listeners with the batch
    cids — dedup acks included (re-referencing an existing chunk must
    still reach an in-flight collection's barrier)."""
    heard = []
    backend.add_put_listener(heard.append)
    raws = chunks(rng, n=5)
    cids = backend.put_many(raws)
    assert heard and heard[-1] == cids
    n0 = len(heard)
    backend.put_many(raws)                          # pure dedup batch
    assert len(heard) > n0 and heard[-1] == cids
    backend.remove_put_listener(heard.append)
    backend.put(encode_chunk(3, rng.bytes(64)))
    assert heard[-1] == cids                        # detached: silent


@all_backends
def test_put_mid_mark_is_shaded_and_survives(backend, rng):
    """A put landing mid-mark must gray its refs on every backend stack:
    the new version survives the epoch even though it was not in the
    root snapshot."""
    from repro.gc import GCPhase
    db = ForkBase(backend)
    keep = rng.bytes(60_000)
    db.put("k1", FBlob(keep))
    db.fork("k1", "master", "tmp")
    db.put("k1", FBlob(rng.bytes(60_000)), "tmp")
    db.remove("k1", "tmp")                          # garbage to collect
    col = db.incremental_gc()
    assert col.step(2) is GCPhase.MARK              # mark in flight
    fresh = rng.bytes(60_000)
    uid = db.put("k2", FBlob(fresh))                # put landing mid-mark
    assert uid in col.marked                        # barrier grayed it
    while col.step(16) is not GCPhase.DONE:
        pass
    assert col.report.swept_chunks > 0
    assert col.report.barriered > 0
    assert db.get("k1").blob().read() == keep
    assert db.get("k2").blob().read() == fresh


@all_backends
def test_dedup_put_mid_sweep_rescues_condemned_chunks(backend, rng):
    """A put landing mid-sweep that dedups against condemned chunks must
    rescue them before their slice is deleted — on every stack."""
    from repro.gc import GCPhase
    db = ForkBase(backend)
    data = rng.bytes(60_000)
    db.put("k", FBlob(data), "tmp")
    db.remove("k", "tmp")                           # whole value condemned
    col = db.incremental_gc()
    while col.step(4) is GCPhase.MARK:
        pass
    assert col.phase is GCPhase.SWEEP               # frozen, nothing swept
    uid = db.put("k", FBlob(data))                  # dedups against condemned
    assert col.report.barriered > 0                 # rescued, not resurrected
    while col.step(4) is not GCPhase.DONE:
        pass
    assert db.get("k", uid=uid).blob().read() == data


# --------------------------------------------------- log: tombstones, compact

def test_log_tombstones_survive_reopen(tmp_path, rng):
    path = str(tmp_path / "chunks.log")
    be = MemoryBackend(log_path=path)
    cids = be.put_many(chunks(rng, n=6))
    be.delete_many(cids[:3])
    be.flush()
    # replay WITHOUT compaction: deletes must not resurrect
    be2 = MemoryBackend(log_path=path)
    assert be2.has_many(cids) == [False] * 3 + [True] * 3
    assert len(be2) == 3


def test_compact_log_shrinks_and_preserves(tmp_path, rng):
    path = str(tmp_path / "chunks.log")
    be = MemoryBackend(log_path=path)
    raws = chunks(rng, n=10, size=800)
    cids = be.put_many(raws)
    be.delete_many(cids[:7])
    before, after = be.compact_log()
    assert after < before
    assert be.log_size() == after
    # compacted log replays to exactly the live set
    be2 = MemoryBackend(log_path=path, verify=True)
    assert len(be2) == 3
    assert be2.get_many(cids[7:]) == raws[7:]
    assert be2.stats.physical_bytes == be.stats.physical_bytes
    # backend stays writable after compaction (handle reopened)
    extra = be.put(encode_chunk(3, rng.bytes(128)))
    be.flush()
    assert MemoryBackend(log_path=path).has(extra)


def test_torn_tail_truncated_so_postcrash_writes_survive(tmp_path, rng):
    """Recovery must truncate the torn record on disk: records appended
    after it (tombstones, new chunks) would otherwise be parsed as the
    torn record's payload on the next replay and silently lost."""
    path = str(tmp_path / "chunks.log")
    be = MemoryBackend(log_path=path)
    cids = be.put_many(chunks(rng, n=3))
    be.flush()
    with open(path, "r+b") as f:        # crash mid-append: torn record
        f.seek(0, 2)
        f.write(b"\x03torn-partial-record")
    be2 = MemoryBackend(log_path=path)  # recovers prefix, truncates tail
    assert len(be2) == 3
    be2.delete_many(cids[:1])           # post-crash tombstone
    extra = be2.put(encode_chunk(3, rng.bytes(99)))
    be2.flush()
    be3 = MemoryBackend(log_path=path)
    assert not be3.has(cids[0])         # tombstone replayed, not eaten
    assert be3.has(extra)               # post-crash put survived
    assert be3.get_many(cids[1:]) == be2.get_many(cids[1:])


def test_compact_without_log_is_noop():
    assert MemoryBackend().compact_log() == (0, 0)


_REPLAY_STATS = ("puts", "logical_bytes", "physical_bytes", "deletes",
                 "reclaimed_bytes", "dedup_hits")


def _replay_stats(be):
    return {f: getattr(be.stats, f) for f in _REPLAY_STATS}


def test_replay_restores_stats(tmp_path, rng):
    """Regression (satellite): replay never restored puts/logical_bytes
    and ignored tombstones in deletes/reclaimed_bytes, so dedup and
    space ratios were wrong after every reopen.  For a workload the log
    fully records (unique chunks + deletes, no compaction) the
    replay-recoverable stats must survive a reopen exactly."""
    path = str(tmp_path / "chunks.log")
    be = MemoryBackend(log_path=path)
    raws = chunks(rng, n=8, size=600)
    cids = be.put_many(raws)
    be.delete_many(cids[:3])
    be.flush()
    want = _replay_stats(be)
    assert want["puts"] == 8 and want["deletes"] == 3
    assert want["logical_bytes"] == sum(len(r) for r in raws)
    be2 = MemoryBackend(log_path=path)
    assert _replay_stats(be2) == want
    assert be2.stats.dedup_ratio == be.stats.dedup_ratio
    # delete + re-put leaves three records; replay must net them out
    be2.delete_many(cids[3:4])
    be2.put(raws[3])
    be2.flush()
    be3 = MemoryBackend(log_path=path)
    assert be3.stats.physical_bytes == be2.stats.physical_bytes
    assert be3.stats.deletes == 4 and be3.stats.puts == 9
    assert sorted(be3.iter_cids()) == sorted(be2.iter_cids())


def test_replay_stats_match_fresh_reexecution(tmp_path, rng):
    """Hypothesis property (satellite): under random put/delete/compact/
    reopen interleavings, a reopened backend converges to the identical
    ``_data`` AND identical stats of a fresh backend that executes
    exactly the log's surviving operations — i.e. replay is
    semantically a re-execution, not just a data load."""
    pytest.importorskip("hypothesis")
    import itertools
    from hypothesis import given, settings, strategies as st
    fresh = itertools.count()          # unique log path per example

    @settings(max_examples=40, deadline=None)
    @given(ops=st.lists(
        st.one_of(
            st.tuples(st.just("put"), st.integers(0, 11)),
            st.tuples(st.just("delete"), st.integers(0, 11)),
            st.tuples(st.just("compact"), st.just(0)),
            st.tuples(st.just("reopen"), st.just(0))),
        min_size=1, max_size=40),
           seed=st.integers(0, 2**31 - 1))
    def prop(ops, seed, tmp_path=tmp_path):
        import numpy as np
        rng = np.random.default_rng(seed)
        pool = chunks(rng, n=12, size=200)
        path = str(tmp_path / f"prop-{next(fresh)}.log")
        be = MemoryBackend(log_path=path)
        # the model: what a fresh store replaying the CURRENT log would
        # count — compaction rewrites the log to the live set only
        model = {f: 0 for f in _REPLAY_STATS}
        for op, i in ops:
            if op == "put":
                raw = pool[i]
                cid = cid_of(raw)
                fresh = not be.has(cid)
                be.put(raw)
                if fresh:            # dedup acks are not logged
                    model["puts"] += 1
                    model["logical_bytes"] += len(raw)
                    model["physical_bytes"] += len(raw)
            elif op == "delete":
                cid = cid_of(pool[i])
                if be.has(cid):
                    be.delete(cid)
                    model["deletes"] += 1
                    model["reclaimed_bytes"] += len(pool[i])
                    model["physical_bytes"] -= len(pool[i])
            elif op == "compact":
                be.compact_log()     # history drops out of the log
                live = sum(len(r) for r in be._data.values())
                model = {f: 0 for f in _REPLAY_STATS}
                model["puts"] = len(be._data)
                model["logical_bytes"] = live
                model["physical_bytes"] = live
            else:
                be.flush()
                data_before = dict(be._data)
                be = MemoryBackend(log_path=path)
                assert be._data == data_before      # identical _data
                assert _replay_stats(be) == model   # identical stats
        be.flush()
        be2 = MemoryBackend(log_path=path)
        assert be2._data == be._data
        assert _replay_stats(be2) == model

    prop()


# ----------------------------------------------------- tamper detection

@pytest.fixture
def verified_backend(request, tmp_path):
    """The same eight stacks, with integrity verification enabled in
    every leaf store (and on the cluster nodes)."""
    name = request.param
    vmem = lambda: MemoryBackend(verify=True)  # noqa: E731
    if name == "memory":
        return vmem()
    if name == "log":
        return MemoryBackend(log_path=str(tmp_path / "chunks.log"),
                             verify=True)
    if name == "lru":
        return LRUCacheBackend(vmem(), capacity_bytes=1 << 20, verify=True)
    if name == "replicated":
        return ReplicatedBackend([vmem() for _ in range(3)], k=2)
    if name == "sharded":
        return ShardedBackend(4, factory=vmem)
    if name == "routing":
        return Cluster(3, verify=True).nodes[0].servlet.store
    if name == "segment":
        return SegmentBackend(str(tmp_path / "segs"),
                              segment_bytes=8 << 10, verify=True)
    if name == "tiered":
        return TieredBackend(
            SegmentBackend(str(tmp_path / "cold"), segment_bytes=8 << 10,
                           verify=True),
            hot_bytes=16 << 10, verify=True)
    raise AssertionError(name)


def _leaf_stores(backend):
    """Every leaf store (MemoryBackend / SegmentBackend) a stack bottoms
    out in."""
    if isinstance(backend, (MemoryBackend, SegmentBackend)):
        return [backend]
    if isinstance(backend, LRUCacheBackend):
        return _leaf_stores(backend.inner)
    if isinstance(backend, TieredBackend):
        return _leaf_stores(backend.cold)
    if isinstance(backend, ReplicatedBackend):
        return [leaf for s in backend.stores for leaf in _leaf_stores(s)]
    if isinstance(backend, ShardedBackend):
        return [leaf for s in backend.shards for leaf in _leaf_stores(s)]
    cluster = getattr(backend, "cluster", None)
    if cluster is not None:
        return [leaf for n in cluster.nodes for leaf in _leaf_stores(n.store)]
    raise AssertionError(type(backend))


def _flip_leaf(leaf, cid) -> int:
    """Flip one byte of ``cid``'s raw inside one leaf store (in the dict
    for MemoryBackend, ON DISK for SegmentBackend)."""
    if isinstance(leaf, MemoryBackend):
        raw = leaf._data.get(cid)
        if raw is None:
            return 0
        leaf._data[cid] = raw[:-1] + bytes([raw[-1] ^ 0x55])
        return 1
    gen = leaf._index.get(cid)
    if gen is None:
        return 0
    leaf.flush()                        # the record must be on disk to flip
    seg = leaf._segments[gen]
    off, ln = seg.live[cid]
    with open(seg.path, "r+b") as f:
        f.seek(off + ln - 1)
        last = f.read(1)[0]
        f.seek(off + ln - 1)
        f.write(bytes([last ^ 0x55]))
    return 1


def _corrupt_everywhere(backend, cid):
    """Flip one byte in EVERY materialization of ``cid`` — all replicas,
    the owning shard/node, any resident cache copy, AND the hot-tier
    copy (a cache/hot tier must not be a verification hole)."""
    hit = 0
    for leaf in _leaf_stores(backend):
        hit += _flip_leaf(leaf, cid)
    if isinstance(backend, LRUCacheBackend):
        raw = backend._cache.get(cid)
        if raw is not None:
            backend._cache[cid] = raw[:-1] + bytes([raw[-1] ^ 0x55])
            hit += 1
    if isinstance(backend, TieredBackend):
        raw = backend._hot.get(cid)
        if raw is not None:
            backend._hot[cid] = raw[:-1] + bytes([raw[-1] ^ 0x55])
            hit += 1
    assert hit > 0
    return hit


@pytest.mark.parametrize("verified_backend", BACKENDS, indirect=True)
def test_corruption_surfaces_tampered_chunk(verified_backend, rng):
    """Conformance: a flipped byte in a stored raw surfaces TamperedChunk
    from get/get_many on every backend stack — corruption can never be
    silently returned to a reader."""
    be = verified_backend
    raws = chunks(rng, n=8)
    cids = be.put_many(raws)
    assert be.get_many(cids) == raws
    assert _stack_stat(be, "verifies") > 0      # reads actually verified
    _corrupt_everywhere(be, cids[2])
    with pytest.raises(TamperedChunk):
        be.get_many(cids)
    with pytest.raises(TamperedChunk):
        be.get(cids[2])
    assert _stack_stat(be, "verify_failures") >= 1
    # untouched chunks still read clean
    ok = [c for i, c in enumerate(cids) if i != 2]
    assert be.get_many(ok) == [r for i, r in enumerate(raws) if i != 2]


def _stack_stat(be, name):
    leaves = _leaf_stores(be)
    total = sum(getattr(leaf.stats, name) for leaf in leaves)
    if all(leaf is not be for leaf in leaves):
        total += getattr(be.stats, name)        # cache/tier-layer checks
    return total


@pytest.mark.parametrize("verified_backend", BACKENDS, indirect=True)
def test_verified_stack_roundtrip_counts_verifies(verified_backend, rng):
    """StoreStats.verifies ticks on the verify-enabled read path and no
    failures are recorded for clean data."""
    be = verified_backend
    cids = be.put_many(chunks(rng, n=5))
    be.get_many(cids)
    assert _stack_stat(be, "verifies") >= 5
    assert _stack_stat(be, "verify_failures") == 0


def test_replay_detects_tampering(tmp_path, rng):
    path = str(tmp_path / "chunks.log")
    be = MemoryBackend(log_path=path)
    raw = encode_chunk(3, rng.bytes(300))
    be.put(raw)
    be.flush()
    blob = open(path, "rb").read()
    open(path, "wb").write(blob[:-1] + bytes([blob[-1] ^ 0xFF]))
    with pytest.raises(TamperedChunk):
        MemoryBackend(log_path=path, verify=True)
    # without verify the tamper goes through (documented trade-off)
    assert len(MemoryBackend(log_path=path)) == 1


def test_put_get_tamper_checks_are_typed(rng):
    be = MemoryBackend(verify=True)
    raw = encode_chunk(3, rng.bytes(100))
    with pytest.raises(TamperedChunk):
        be.put(raw, cid=bytes(32))                  # wrong caller cid
    cid = be.put(raw)
    be._data[cid] = raw[:-1] + bytes([raw[-1] ^ 1])
    with pytest.raises(TamperedChunk):
        be.get(cid)


# ------------------------------------------------------- batched pipeline

@pytest.mark.parametrize("backend", ["memory"], indirect=True)
def test_value_commits_in_one_batch(backend, rng):
    """Acceptance: N-chunk value -> one put_many (batch calls << chunks)."""
    db = ForkBase(backend)
    db.put("blob", FBlob(rng.bytes(300_000)))
    st = backend.stats
    assert st.put_batches == 1
    assert st.puts > 20 * st.put_batches
    db.put("map", FMap({b"k%04d" % i: rng.bytes(64) for i in range(3000)}))
    assert st.put_batches == 2
    assert st.puts > 20 * st.put_batches


@pytest.mark.parametrize("backend", ["memory"], indirect=True)
def test_write_buffer_nests_and_passes_through(backend, rng):
    outer = WriteBuffer(backend)
    inner = WriteBuffer(outer)
    raws = chunks(rng, n=6)
    cids = inner.put_many(raws)
    assert inner.get_many(cids) == raws       # reads see pending chunks
    assert len(backend) == 0
    inner.flush()
    assert len(backend) == 0                  # still buffered in outer
    outer.flush()
    assert backend.stats.put_batches == 1     # ONE real store round-trip
    assert backend.get_many(cids) == raws
    # closed buffers are transparent: writes land directly in the store
    extra = inner.put(encode_chunk(3, rng.bytes(50)))
    assert backend.has(extra)


@pytest.mark.parametrize("backend", ["lru"], indirect=True)
def test_lru_serves_repeat_reads_from_cache(backend, rng):
    cids = backend.put_many(chunks(rng, n=8))
    backend.inner.stats.gets = 0
    backend.get_many(cids)
    backend.get_many(cids)
    assert backend.inner.stats.gets == 0      # write-through populated it
    assert backend.stats.cache_hits == 16


@pytest.mark.parametrize("backend", ["replicated"], indirect=True)
def test_replicated_reads_stay_batched(backend, rng):
    """get_many groups by primary replica: O(replicas) inner batches,
    not one batch-of-one per cid."""
    raws = chunks(rng, n=30)
    cids = backend.put_many(raws)
    g0 = sum(s.stats.get_batches for s in backend.stores)
    assert backend.get_many(cids) == raws
    assert sum(s.stats.get_batches for s in backend.stores) - g0 <= \
        len(backend.stores)


@pytest.mark.parametrize("backend", ["replicated"], indirect=True)
def test_replication_factor_and_failover(backend, rng):
    raw = encode_chunk(3, rng.bytes(1500))
    cid = backend.put(raw)
    assert sum(1 for s in backend.stores if s.has(cid)) == backend.k
    for s in backend.stores:                  # kill the primary replica
        if s.has(cid):
            del s._data[cid]
            break
    assert backend.get(cid) == raw            # failover to the other copy
    with pytest.raises(ChunkMissing):
        backend.get_many([bytes(32)])


@pytest.mark.parametrize("backend", ["sharded"], indirect=True)
def test_sharding_spreads_chunks(backend, rng):
    backend.put_many(chunks(rng, n=200))
    dist = [len(s) for s in backend.shards]
    assert sum(dist) == 200
    assert min(dist) > 0                      # cid hash spreads uniformly


@pytest.mark.parametrize("backend", ["memory"], indirect=True)
def test_make_backend_specs(backend, tmp_path, rng):
    for spec, kw in [("memory", {}), ("lru+memory", {}),
                     ("lru+sharded", {"shards": 2}),
                     ("replicated", {"n": 3, "k": 2}),
                     ("log", {"log_path": str(tmp_path / "l.log")}),
                     ("segment", {"root": str(tmp_path / "segs")}),
                     ("tiered", {"root": str(tmp_path / "tier")})]:
        b = make_backend(spec, **kw)
        raw = encode_chunk(3, rng.bytes(128))
        assert b.get(b.put(raw)) == raw
    with pytest.raises(ValueError):
        make_backend("bogus")


@pytest.mark.parametrize("backend", ["memory"], indirect=True)
def test_fphash_many_matches_per_chunk_kernel(backend, rng):
    from repro.kernels.fphash import fphash, fphash_many
    blobs = [rng.bytes(n) for n in (0, 1, 300, 4096, 4097, 9000)]
    assert fphash_many(blobs) == [fphash(b) for b in blobs]


@pytest.mark.parametrize("backend", ["memory"], indirect=True)
def test_fphash_dispatch_roundtrip(backend, rng):
    """use_fphash(): cids route through the batched Pallas kernel; the
    engine works identically (one launch per value commit)."""
    from repro.core import hashing
    hashing.use_fphash()
    try:
        db = ForkBase(backend)
        data = rng.bytes(50_000)
        db.put("k", FBlob(data))
        assert db.get("k").blob().read() == data
        assert backend.stats.put_batches == 1
    finally:
        hashing.use_sha256()
