"""Chunk storage durability + replication + cluster rebalancing
(paper §4.4, §4.6.1)."""

from repro.core import ChunkParams, ChunkStore, Cluster, FBlob, ReplicatedStore
from repro.core.chunk import cid_of, encode_chunk


def test_log_persistence_and_replay(tmp_path, rng):
    log = str(tmp_path / "chunks.log")
    s = ChunkStore(log_path=log)
    cids = [s.put(encode_chunk(3, rng.bytes(500))) for _ in range(20)]
    s.flush()
    s2 = ChunkStore(log_path=log)          # replay
    for c in cids:
        assert s2.has(c)
        assert cid_of(s2.get(c)) == c


def test_log_torn_tail_recovery(tmp_path, rng):
    log = str(tmp_path / "chunks.log")
    s = ChunkStore(log_path=log)
    cids = [s.put(encode_chunk(3, rng.bytes(300))) for _ in range(10)]
    s.flush()
    with open(log, "ab") as f:             # simulate torn write at crash
        f.write(b"\x00" * 17)
    s2 = ChunkStore(log_path=log)
    for c in cids:                          # prefix fully recovered
        assert s2.has(c)


def test_replicated_store_failover(rng):
    stores = [ChunkStore() for _ in range(4)]
    rs = ReplicatedStore(stores, k=2)
    cid = rs.put(encode_chunk(3, rng.bytes(1000)))
    # exactly k replicas exist
    assert sum(1 for s in stores if s.has(cid)) == 2
    # kill the primary replica: get() fails over
    for s in stores:
        if s.has(cid):
            del s._data[cid]
            break
    assert cid_of(rs.get(cid)) == cid


def test_dedup_across_replicated_puts(rng):
    stores = [ChunkStore() for _ in range(3)]
    rs = ReplicatedStore(stores, k=2)
    raw = encode_chunk(3, rng.bytes(2000))
    rs.put(raw)
    rs.put(raw)                              # duplicate put
    total = sum(s.stats.physical_bytes for s in stores)
    assert total == 2 * len(raw)             # k copies, not 2k (§4.4)


def test_cluster_build_rebalancing(rng):
    """§4.6.1: an overloaded servlet delegates POS-Tree construction to
    the least-loaded peer — build work spreads even when one key is hot."""
    cl = Cluster(4, "2LP", ChunkParams(q=8))
    for i in range(60):
        cl.put("hotkey", FBlob(rng.bytes(30000)), branch=f"b{i}")
    dist = cl.build_distribution()
    assert max(dist) < 0.75 * sum(dist), dist   # not all on one node


def test_meta_chunks_stay_local(rng):
    """§4.6: meta chunks pin to the key's servlet; data chunks spread."""
    cl = Cluster(4, "2LP", ChunkParams(q=8))
    cl.put("k", FBlob(rng.bytes(50000)))
    from repro.core.cluster import _h
    home = _h(b"k") % 4
    from repro.core import chunk as ck
    meta_nodes = set()
    for cid, node in cl.index.items():
        # repro: allow(PERF001): each cid lives on a different node —
        # there is no single store to batch against
        raw = cl.nodes[node].store.get(cid)
        if ck.chunk_type(raw) == ck.META:
            meta_nodes.add(node)
    assert meta_nodes == {home}
