"""End-to-end behaviour tests: the full stack working together —
ForkBase engine + typed objects + fork semantics + the training framework
checkpointing through it."""
import jax
import pytest

pytestmark = pytest.mark.slow  # ~minutes of model/train work

from repro.apps import ForkBaseLedger
from repro.configs import ARCHS, smoke
from repro.core import ChunkParams, FMap, ForkBase
from repro.runtime import run_resilient
from repro.shardings import Sharding
from repro.train import AdamWConfig, init_train_state, make_train_step
from repro.train.data import SyntheticLM


def test_end_to_end_collaboration(rng):
    """Two 'analysts' fork a dataset, edit independently, merge cleanly;
    storage stays deduplicated; history stays verifiable."""
    db = ForkBase(params=ChunkParams(q=8))
    m = FMap({f"row{i:04d}".encode(): rng.bytes(40) for i in range(800)})
    base_uid = db.put("data", m)
    db.fork("data", "master", "alice")
    db.fork("data", "master", "bob")
    ma = db.get("data", "alice").map()
    ma.set(b"row0001", b"alice-edit")
    ua = db.put("data", ma, "alice")
    mb = db.get("data", "bob").map()
    mb.set(b"row0500", b"bob-edit")
    ub = db.put("data", mb, "bob")
    db.merge("data", "master", "alice")
    db.merge("data", "master", "bob")
    final = db.get("data", "master").map()
    assert final.get(b"row0001") == b"alice-edit"
    assert final.get(b"row0500") == b"bob-edit"
    head = db.get("data", "master").uid
    assert db.verify_lineage(head, base_uid)
    assert db.store.stats.dedup_ratio > 1.15  # forks+merges share chunks


def test_end_to_end_training_with_storage(rng):
    """Train a reduced model through failures, checkpointing into the same
    ForkBase instance that serves a blockchain app — shared storage,
    shared dedup pool."""
    db = ForkBase(params=ChunkParams(q=12))
    ledger = ForkBaseLedger(db)
    sc = smoke(ARCHS["internlm2-1.8b"])
    shd = Sharding(None, sc)
    state = init_train_state(sc, jax.random.PRNGKey(0), shards=4)
    ds = SyntheticLM(sc.vocab, 64, 4)
    step = jax.jit(make_train_step(sc, shd,
                                   AdamWConfig(warmup_steps=2)))
    ctl = run_resilient(step, state, ds, n_steps=6, fail_at=(4,),
                        ckpt_every=2, db=db)
    assert ctl.step == 6 and ctl.restarts == 1
    # blockchain records the training lineage (model provenance on-chain)
    for s, _meta in ctl.ckpt.history("run"):
        ledger.write("provenance", "ckpt", s.hex().encode())
    ledger.commit()
    hist = ledger.state_scan("provenance", "ckpt")
    assert len(hist) == 1
    assert ledger.verify_block(0)


def test_smoke_all_archs_shapes_defined():
    from repro.configs import SHAPES, input_specs, shapes_for
    total = 0
    for _name, cfg in ARCHS.items():
        for sh in shapes_for(cfg):
            specs = input_specs(cfg, SHAPES[sh])
            assert all(hasattr(s, "shape") for s in specs.values())
            total += 1
    assert total == 32   # 10x3 + 2 long_500k (8 skips documented)
