"""Training substrate + fault tolerance + ForkBase checkpointing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # ~minutes of model/train work

from repro.ckpt import CheckpointStore
from repro.configs import ARCHS, smoke
from repro.runtime import run_resilient
from repro.shardings import Sharding
from repro.train import (AdamWConfig, init_train_state, make_train_step,
                         schedule)
from repro.train.data import SyntheticLM


@pytest.fixture(scope="module")
def setup():
    sc = smoke(ARCHS["tinyllama-1.1b"])
    shd = Sharding(None, sc)
    state = init_train_state(sc, jax.random.PRNGKey(0), shards=4)
    ds = SyntheticLM(sc.vocab, 64, 8)
    step = jax.jit(make_train_step(
        sc, shd, AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100)))
    return sc, shd, state, ds, step


def test_loss_decreases(setup):
    sc, shd, state, ds, step = setup
    losses = []
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_microbatch_equivalence():
    """Gradient accumulation over microbatches ~ single large batch."""
    sc = smoke(ARCHS["internlm2-1.8b"])
    shd = Sharding(None, sc)
    state = init_train_state(sc, jax.random.PRNGKey(0), shards=4)
    ds = SyntheticLM(sc.vocab, 32, 8)
    b = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
    s1, m1 = jax.jit(make_train_step(sc, shd, AdamWConfig()))(state, b)
    s2, m2 = jax.jit(make_train_step(sc, shd, AdamWConfig(),
                                     microbatch=4))(state, b)
    for a, c in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32), atol=3e-2)


def test_schedule():
    opt = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(schedule(opt, jnp.asarray(0))) < 0.2
    assert float(schedule(opt, jnp.asarray(10))) > 0.9
    assert abs(float(schedule(opt, jnp.asarray(100))) - 0.1) < 1e-5


def test_failure_recovery_bitexact(setup):
    sc, shd, state, ds, step = setup
    a = run_resilient(step, state, ds, n_steps=8, ckpt_every=3)
    b = run_resilient(step, state, ds, n_steps=8, fail_at=(5,),
                      ckpt_every=3)
    assert b.restarts == 1
    for x, y in zip(jax.tree.leaves(a.state["params"]),
                    jax.tree.leaves(b.state["params"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_multiple_failures(setup):
    sc, shd, state, ds, step = setup
    ctl = run_resilient(step, state, ds, n_steps=10, fail_at=(3, 6, 6),
                        ckpt_every=2)
    assert ctl.step == 10 and ctl.restarts >= 2


def test_ckpt_fork_and_lineage(setup):
    sc, shd, state, ds, step = setup
    ck = CheckpointStore()
    ck.save(state, "main", step=0)
    state2, _ = step(state, {k: jnp.asarray(v)
                             for k, v in ds.batch_at(0).items()})
    u1 = ck.save(state2, "main", step=1)
    ck.fork("main", "sweep")
    r = ck.restore(state, "sweep")
    for x, y in zip(jax.tree.leaves(r["params"]),
                    jax.tree.leaves(state2["params"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    hist = ck.history("main")
    assert ck.verify(u1, hist[-1][0])


def test_foc_racing_pods(setup):
    sc, shd, state, ds, step = setup
    ck = CheckpointStore()
    ck.save(state, "run", step=4)
    base = ck.db.get("ckpt", "run").uid
    sA, _ = step(state, {k: jnp.asarray(v)
                         for k, v in ds.batch_at(4).items()})
    uA = ck.save_on_base(sA, base, step=5)
    uB = ck.save_on_base(state, base, step=4)
    heads = ck.racing_heads()
    assert uA in heads and uB in heads
    winner = ck.resolve_race(uA, uB)
    assert winner in ck.racing_heads()


def test_elastic_restore_roundtrip(setup):
    """Checkpoint is mesh-agnostic: restore onto a 'different' topology
    (here: device_put with explicit single-device sharding specs)."""
    sc, shd, state, ds, step = setup
    ck = CheckpointStore()
    ck.save(state, "run", step=0)
    restored = ck.restore(state, "run")
    for x, y in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
